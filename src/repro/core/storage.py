"""Replicated object store over shared WAN links — ``storage_batch``.

A storage broker receives a stream of object PUTs, each originating at a
client site, and places ``n_replicas`` copies of every object — at its
submission event — on the storage nodes that minimize its *placement-
weighted commit time*: WAN transfer delay over the inter-site
latency/bandwidth matrix (:class:`repro.core.network.InterDCTopology`),
queueing behind the writes already committed to each node (single FIFO
writer at ``write_bw[d]`` bytes/s), and the write itself.  The object
*commits* when its ``quorum``-th replica finishes (N-way replication =
``quorum == n_replicas``; quorum replication = ``quorum < n_replicas``).

Fault semantics (the scenario's reason to exist): a node fault window
(:class:`~repro.core.faults.FaultPlan`, kind ``node``) that overlaps a
replica's transfer *mid-flight* kills that upload — the node's writer is
occupied until the window clears — and the broker re-sources the lost
copy from the earliest *surviving* replica of the same object (a repair
transfer starting at ``max(window clear, first surviving finish)``).  A
repair that is itself hit by a window fails permanently.  ``link``
windows degrade every WAN transfer submitted inside them; ``transient``
windows make the PUT itself flaky (shared retry machinery); a finite
``timeout_s`` drops replicas no node can land inside the deadline, and
an object is *dropped* when fewer than ``quorum`` replicas survive.

This module owns everything both backends share — the libm-free workload
generator, the per-cell placement tables (transfer/service/bias
matrices, all precomputed host-side so neither backend multiplies inside
its decision loop — no FMA-contraction hazard), the placement rule
itself (:func:`place_object`, scalar form), and the host-side summary —
plus the OO reference: a broker entity driving OBJECT_PUT/OBJECT_COMMIT
events through a ``Simulation`` with live fault counters.  The vec
implementation (:mod:`repro.core.vec_storage`) is a thin
:class:`~repro.core.vec_engine.VecEngine` over the same tables.

Exactness contract (asserted by the differential suite and golden
fixtures): ``oo`` and ``vec`` agree **bit-exactly** on every output —
the decision arithmetic is adds/max/min/compares over shared precomputed
f64 tables, and ties break to the lowest node index on both paths.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Sequence

import numpy as np

from .backend import SimBackend, scenario
from .engine import SimEntity, Simulation
from .events import Event, Tag
from .faults import FaultInjector, FaultPlan, RetryPolicy, apply_transient
from .network import InterDCTopology


def default_write_bw(n_nodes: int) -> np.ndarray:
    """Heterogeneous default write rates: four repeating device classes
    (think HDD pool / SATA SSD / NVMe / NVMe-oF), in bytes/s."""
    return np.asarray([200e6 + 150e6 * (d % 4) for d in range(n_nodes)],
                      np.float64)


def storage_workload(rng: random.Random, n_objects: int, n_nodes: int, *,
                     mean_gap_s: float, size_mb) -> Dict[str, Any]:
    """One seed's PUT stream: nondecreasing submit times (uniform gaps),
    uniform client site, uniform object size (bytes).  Libm-free for the
    same reason as :func:`repro.core.netdc.netdc_workload` — golden
    fixtures must be bit-stable across platforms."""
    t = 0.0
    submit, src, size = [], [], []
    for j in range(n_objects):
        if j:
            t += rng.uniform(0.0, 2.0 * mean_gap_s)
        submit.append(t)
        src.append(rng.randrange(n_nodes))
        size.append(rng.uniform(*size_mb) * 1e6)
    return dict(submit=np.asarray(submit, np.float64),
                src=np.asarray(src, np.int32),
                size=np.asarray(size, np.float64))


class StorageFaults(NamedTuple):
    """Per-cell fault context (present iff the cell was built faulted).
    Mirrors :class:`repro.core.netdc.NetdcFaults`: the OO broker replays
    ``windows`` live through a :class:`~repro.core.faults.FaultInjector`
    for the submit-time eligibility mask, while both backends evaluate
    the same window list for mid-transfer kills."""
    windows: tuple             # ((target, t_start, t_end), ...) node windows
    static_online: np.ndarray  # [D] bool offline_node mask (no fault fold)
    gave_up: np.ndarray        # [J] bool transient retries/budget exhausted
    attempts: np.ndarray       # [J] i64 attempts made per object (>= 1)
    perm: np.ndarray           # [J] i64 stable effective-submit order
    timeout_s: float           # replica deadline: submit + timeout_s


@dataclass(frozen=True)
class StorageCell:
    """One cell's precomputed placement tables — shared verbatim by the
    OO broker and the vec engine.  ``win_*`` carry the node fault windows
    both backends test transfers against (empty when unfaulted)."""
    submit: np.ndarray        # [J] f64 nondecreasing (effective) submits
    src: np.ndarray           # [J] i32 client site per object
    size: np.ndarray          # [J] f64 bytes
    xfer: np.ndarray          # [J, D] f64 WAN transfer delay to each node
    serve: np.ndarray         # [J, D] f64 write service time on each node
    bias: np.ndarray          # [J, D] f64 (placement_weight - 1) · xfer
    online: np.ndarray        # [J, D] bool submit-time candidate mask
    win_tgt: np.ndarray       # [W] i64 node fault-window targets
    win_ts: np.ndarray        # [W] f64 window starts
    win_te: np.ndarray        # [W] f64 window ends
    fx: Optional[StorageFaults] = None


def build_cell(seed: int, n_nodes: int, n_objects: int,
               write_bw: np.ndarray, topo: InterDCTopology,
               placement_weight: float, offline_node: int, *,
               mean_gap_s: float, size_mb,
               fault_plan: Optional[FaultPlan] = None,
               retry: Optional[RetryPolicy] = None,
               timeout_s: float = math.inf,
               workload: Optional[Dict[str, Any]] = None) -> StorageCell:
    """Workload + placement tables for one (seed, weight, outage) cell.
    An injected ``workload`` (a validated trace-replay stream) replaces
    the seeded generator — every cell then shares the recorded stream."""
    wl = (workload if workload is not None else
          storage_workload(random.Random(int(seed)), n_objects, n_nodes,
                           mean_gap_s=mean_gap_s, size_mb=size_mb))
    online0 = np.ones(n_nodes, bool)
    if offline_node >= 0:
        online0[offline_node] = False
    zf, zi = np.empty(0, np.float64), np.empty(0, np.int64)
    if fault_plan is None and not math.isfinite(timeout_s):
        xfer = topo.delay_rows(wl["src"], wl["size"])
        return StorageCell(
            submit=wl["submit"], src=wl["src"], size=wl["size"], xfer=xfer,
            serve=wl["size"][:, None] / write_bw[None, :],
            bias=(float(placement_weight) - 1.0) * xfer,
            online=np.repeat(online0[None, :], n_objects, axis=0),
            win_tgt=zi, win_ts=zf, win_te=zf)

    plan = fault_plan if fault_plan is not None else FaultPlan()
    # Transient failures resolve at the *original* submit times, then a
    # stable sort restores nondecreasing effective-submit order — the
    # shared event order both backends process.
    out = apply_transient(plan, retry, wl["submit"],
                          seed=plan.seed * 1_000_003 + int(seed))
    perm = np.argsort(out.eff_submit, kind="stable")
    submit = out.eff_submit[perm]
    src, size = wl["src"][perm], wl["size"][perm]
    gave_up = out.gave_up[perm]
    xfer = topo.delay_rows(src, size)
    if plan.has("link"):
        xfer = xfer * plan.degrade_factor(submit, n_nodes)
    online = np.repeat(online0[None, :], n_objects, axis=0)
    windows = ()
    if plan.has("node"):
        online &= ~plan.down_mask("node", submit, n_nodes)
        tgt, ts, te, _ = plan.select("node")
        windows = tuple(zip(tgt.tolist(), ts.tolist(), te.tolist()))
    online &= ~gave_up[:, None]
    # ``target = -1`` node windows (whole-store blackouts) expand to every
    # node so the mid-transfer test stays a flat per-window compare.
    expanded = [(d, a, z) for t, a, z in windows
                for d in ([int(t)] if t >= 0 else range(n_nodes))]
    return StorageCell(
        submit=submit, src=src, size=size, xfer=xfer,
        serve=size[:, None] / write_bw[None, :],
        bias=(float(placement_weight) - 1.0) * xfer, online=online,
        win_tgt=np.asarray([w[0] for w in expanded], np.int64),
        win_ts=np.asarray([w[1] for w in expanded], np.float64),
        win_te=np.asarray([w[2] for w in expanded], np.float64),
        fx=StorageFaults(windows=windows, static_online=online0,
                         gave_up=gave_up, attempts=out.attempts[perm],
                         perm=perm, timeout_s=float(timeout_s)))


def _window_kill(cell: StorageCell, d: int, start: float, fin: float):
    """Does any node fault window on ``d`` overlap the half-open transfer
    interval ``[start, fin)``?  Returns ``(killed, clear_time)`` — the
    writer stays occupied until the latest overlapping window ends."""
    clear, killed = -math.inf, False
    for w in range(len(cell.win_tgt)):
        if cell.win_tgt[w] == d and cell.win_ts[w] < fin \
                and start < cell.win_te[w]:
            killed = True
            if cell.win_te[w] > clear:
                clear = float(cell.win_te[w])
    return killed, clear


def place_object(free, cell: StorageCell, j: int, n_replicas: int,
                 quorum: int, online=None, deadline: float = math.inf):
    """The placement rule, scalar form (the OO broker's inner loop).

    Phase 1 — sequential replica placement: for each of ``n_replicas``
    copies, pick the first-occurrence argmin of ``fin + bias`` over
    online nodes not already holding a copy whose transfer lands by
    ``deadline`` (``fin = max(free[d], submit + xfer[d]) + serve[d]``);
    a transfer overlapped by a node fault window is *killed* and the
    writer is occupied until the window clears.  Phase 2 — re-sourcing:
    every killed replica restarts from the earliest surviving replica
    (``start = max(window clear, first surviving finish)``); a repair
    killed again fails permanently.  The object commits at the
    ``quorum``-th smallest surviving finish.

    The vec engine evaluates the identical phases with the replica and
    window loops unrolled (``ops.argmin`` shares the first-occurrence
    tie rule).  Returns ``(commit, dst, n_ok, n_killed, n_repaired)``
    with ``commit = inf``/``dst = -1`` when fewer than ``quorum``
    replicas survive; ``free`` is updated in place.
    """
    elig = cell.online[j] if online is None else online
    arr = cell.submit[j] + cell.xfer[j]
    picks, fins, clears = [], [], []
    chosen = [False] * len(free)
    for _ in range(n_replicas):
        best, best_score, best_fin = -1, math.inf, math.inf
        for d in range(len(free)):
            if not elig[d] or chosen[d]:
                continue
            start = free[d] if free[d] > arr[d] else arr[d]
            fin = start + cell.serve[j][d]
            if fin > deadline:
                continue
            score = fin + cell.bias[j][d]
            if score < best_score:
                best, best_score, best_fin = d, score, fin
        if best < 0:
            picks.append(-1)
            fins.append(math.inf)
            clears.append(-math.inf)
            continue
        start = free[best] if free[best] > arr[best] else arr[best]
        killed, clear = _window_kill(cell, best, start, best_fin)
        chosen[best] = True
        picks.append(best)
        fins.append(math.inf if killed else best_fin)
        clears.append(clear)
        free[best] = clear if killed else best_fin
    n_killed = sum(1 for p, f in zip(picks, fins)
                   if p >= 0 and not math.isfinite(f))
    first_ok = min((f for f in fins if math.isfinite(f)), default=math.inf)
    n_repaired = 0
    if n_killed and math.isfinite(first_ok):
        for r in range(n_replicas):
            d = picks[r]
            if d < 0 or math.isfinite(fins[r]):
                continue
            rep_start = clears[r] if clears[r] > first_ok else first_ok
            rep_fin = rep_start + cell.serve[j][d]
            killed, clear = _window_kill(cell, d, rep_start, rep_fin)
            free[d] = clear if killed else rep_fin
            if not killed:
                fins[r] = rep_fin
                n_repaired += 1
    ok = sorted(f for f in fins if math.isfinite(f))
    n_ok = len(ok)
    if n_ok < quorum:
        return math.inf, -1, n_ok, n_killed, n_repaired
    commit = ok[quorum - 1]
    best_r = min(range(n_replicas), key=lambda r: (fins[r], r))
    return commit, picks[best_r], n_ok, n_killed, n_repaired


def summarize(out: Dict[str, Any], cells: Sequence[StorageCell]
              ) -> Dict[str, Any]:
    """Batch-level metrics from per-object ``finish``/``dst``/``n_ok`` —
    one shared numpy routine so every aggregate is computed identically
    for both backends (cf. :func:`repro.core.netdc.summarize`).  Under
    faults the per-object arrays are unsorted back to original submit
    order and the summary gains ``served``/``dropped``/``retries``."""
    out = dict(out)
    finish = out["finish"] = np.asarray(out["finish"], np.float64)
    dst = out["dst"] = np.asarray(out["dst"], np.int64)
    n_ok = out["n_ok"] = np.asarray(out["n_ok"], np.int64)
    killed = out["killed"] = np.asarray(out["killed"], np.int64)
    repaired = out["repaired"] = np.asarray(out["repaired"], np.int64)
    submit = np.stack([c.submit for c in cells])
    size = np.stack([c.size for c in cells])
    n_nodes = cells[0].xfer.shape[-1]
    d_iota = np.arange(n_nodes)
    srv = dst >= 0
    out["makespan"] = np.max(np.where(srv, finish, -np.inf), axis=-1)
    out["commit_total_s"] = np.sum(
        np.where(srv, finish - submit, 0.0), axis=-1)
    out["replicas_ok"] = np.sum(n_ok, axis=-1)
    out["bytes_stored"] = np.sum(size * n_ok, axis=-1)
    out["killed_transfers"] = np.sum(killed, axis=-1)
    out["repaired_transfers"] = np.sum(repaired, axis=-1)
    out["node_primaries"] = np.sum(dst[:, :, None] == d_iota, axis=1)
    out["busiest_node"] = np.argmax(out["node_primaries"], axis=-1)
    if cells and cells[0].fx is not None:
        inv = np.stack([np.argsort(c.fx.perm) for c in cells])
        for k in ("finish", "dst", "n_ok", "killed", "repaired"):
            out[k] = np.take_along_axis(out[k], inv, axis=-1)
        out["submit"] = np.take_along_axis(submit, inv, axis=-1)
        out["served"] = np.sum(srv, axis=-1)
        out["dropped"] = srv.shape[-1] - out["served"]
        out["retries"] = np.stack(
            [np.sum(c.fx.attempts - 1) for c in cells])
    return out


def build_cells(*, seeds, n_nodes: int, n_objects: int, write_bw,
                link_bw: float, hop_latency_s: float, n_replicas: int,
                quorum: int, placement_weight, offline_node,
                mean_gap_s: float, size_mb,
                fault_plan: Optional[FaultPlan] = None,
                retry: Optional[RetryPolicy] = None,
                timeout_s: float = math.inf, workload=None):
    """Validated per-cell table construction — the shared front half of
    both backends' batch handlers."""
    if workload is not None:
        from .trace import check_workload
        workload, n_objects = check_workload(
            "storage_batch", workload,
            dict(submit=np.float64, src=np.int32, size=np.float64),
            n_targets=n_nodes)
        if np.any(workload["size"] <= 0):
            raise ValueError("storage_batch: workload sizes must be > 0")
    if n_objects < 1 or n_nodes < 1:
        raise ValueError("storage_batch needs n_objects ≥ 1 and "
                         "n_nodes ≥ 1")
    n_replicas, quorum = int(n_replicas), int(quorum)
    if not 1 <= quorum <= n_replicas:
        raise ValueError(f"quorum must be in [1, n_replicas]: "
                         f"{quorum} vs {n_replicas}")
    if n_replicas > n_nodes:
        raise ValueError(f"n_replicas ({n_replicas}) cannot exceed "
                         f"n_nodes ({n_nodes})")
    write_bw = (default_write_bw(n_nodes) if write_bw is None
                else np.asarray(write_bw, np.float64))
    if write_bw.shape != (n_nodes,) or not np.all(write_bw > 0):
        raise ValueError(f"write_bw must be {n_nodes} positive rates")
    if not timeout_s > 0:
        raise ValueError(f"storage_batch: timeout_s must be > 0: "
                         f"{timeout_s}")
    if fault_plan is not None:
        if fault_plan.has("region"):
            raise ValueError("storage_batch has no region concept — use "
                             "'node' faults on storage-node targets")
        fault_plan.check_targets("node", n_nodes, "storage node")
        fault_plan.check_targets("link", n_nodes, "storage node")
    from .vec_engine import broadcast_cells
    seeds, axes, b = broadcast_cells(seeds, dict(
        placement_weight=placement_weight, offline_node=offline_node))
    weights = axes["placement_weight"].astype(np.float64)
    offs = axes["offline_node"].astype(np.int64)
    if b and np.max(offs) >= n_nodes:
        raise ValueError(f"offline_node must be < n_nodes={n_nodes}")
    if b and np.any(offs >= 0) and n_replicas > n_nodes - 1:
        raise ValueError("offline_node leaves fewer nodes than "
                         "n_replicas — shrink the replication factor")
    topo = InterDCTopology(n_nodes, link_bw=link_bw,
                           hop_latency_s=hop_latency_s)
    cells = [build_cell(int(seeds[i]), n_nodes, n_objects, write_bw, topo,
                        float(weights[i]), int(offs[i]),
                        mean_gap_s=mean_gap_s, size_mb=size_mb,
                        fault_plan=fault_plan, retry=retry,
                        timeout_s=timeout_s, workload=workload)
             for i in range(b)]
    return cells, b


def empty_storage_outputs(n_nodes: int, faulted: bool = False
                          ) -> Dict[str, np.ndarray]:
    zf, zi = np.empty((0,), np.float64), np.empty((0,), np.int64)
    zjf, zji = np.empty((0, 0), np.float64), np.empty((0, 0), np.int64)
    out = dict(finish=zjf, dst=zji, n_ok=zji, killed=zji, repaired=zji,
               makespan=zf, commit_total_s=zf, replicas_ok=zi,
               bytes_stored=zf, killed_transfers=zi, repaired_transfers=zi,
               node_primaries=np.empty((0, n_nodes), np.int64),
               busiest_node=zi, iterations=np.empty((0,), np.int32))
    if faulted:
        out.update(submit=zjf, served=zi, dropped=zi, retries=zi)
    return out


# -- OO reference: an event-driven broker inside a Simulation ------------------

class StorageBroker(SimEntity):
    """Places each object's replica set at its OBJECT_PUT event and
    collects its OBJECT_COMMIT — the discrete-event reference the vec
    engine compiles into one ``lax.while_loop``."""

    def __init__(self, sim: Simulation, cell: StorageCell, n_replicas: int,
                 quorum: int):
        super().__init__(sim, "storage-broker")
        self.cell = cell
        self.n_replicas, self.quorum = int(n_replicas), int(quorum)
        n = len(cell.submit)
        n_nodes = cell.xfer.shape[1]
        self.free = [0.0] * n_nodes
        self.finish = np.full(n, np.inf)
        self.dst = np.full(n, -1, np.int64)
        self.n_ok = np.zeros(n, np.int64)
        self.killed = np.zeros(n, np.int64)
        self.repaired = np.zeros(n, np.int64)
        self.committed = 0
        # Live submit-time eligibility, the event-driven twin of the
        # precomputed ``cell.online`` table (cf. MultiDCBroker): node
        # windows arrive as NODE_FAILURE/NODE_RECOVER events at priority
        # -1 and overlapping windows nest via per-node down counters.
        # Mid-transfer kills read the window tables directly — they test
        # *future* overlap, which no event at submit time can know.
        self.down_ct = [0] * n_nodes
        if cell.fx is not None and cell.fx.windows:
            FaultInjector(sim, cell.fx.windows, self._apply_fault)

    def _apply_fault(self, target: int, down: bool) -> None:
        delta = 1 if down else -1
        for d in ([target] if target >= 0 else range(len(self.down_ct))):
            self.down_ct[d] += delta

    def start(self) -> None:
        for j, t in enumerate(self.cell.submit):
            self.sim.schedule(float(t), Tag.OBJECT_PUT, self, data=j)

    def process_event(self, ev: Event) -> None:
        c = self.cell
        if ev.tag is Tag.OBJECT_PUT:
            j = ev.data
            fx = c.fx
            if fx is None:
                online, deadline = c.online[j], np.inf
            else:
                if fx.gave_up[j]:
                    return                       # dropped: dst/finish stay
                online = [fx.static_online[d] and self.down_ct[d] == 0
                          for d in range(len(self.free))]
                deadline = c.submit[j] + fx.timeout_s
            commit, dst, n_ok, killed, repaired = place_object(
                self.free, c, j, self.n_replicas, self.quorum,
                online=online, deadline=deadline)
            self.n_ok[j] = n_ok
            self.killed[j] = killed
            self.repaired[j] = repaired
            if dst < 0:
                return                           # below quorum: dropped
            self.dst[j] = dst
            self.finish[j] = commit
            self.sim.schedule(float(commit), Tag.OBJECT_COMMIT, self,
                              data=j)
        elif ev.tag is Tag.OBJECT_COMMIT:
            self.committed += 1


@scenario("storage_batch", backends=("legacy", "oo"))
def _storage_batch_oo(backend: SimBackend, *, seeds=(0,), n_nodes: int = 4,
                      n_objects: int = 64, write_bw=None,
                      n_replicas: int = 2, quorum: int = 1,
                      placement_weight=1.0, offline_node=-1,
                      link_bw: float = 10e9, hop_latency_s: float = 0.02,
                      mean_gap_s: float = 2.0, size_mb=(10.0, 200.0),
                      fault_plan: Optional[FaultPlan] = None,
                      retry: Optional[RetryPolicy] = None,
                      timeout_s: float = np.inf, workload=None,
                      chunk_size: Optional[int] = None,
                      with_report: bool = False, **_ignored):
    """Reference semantics for ``storage_batch``: one event-driven broker
    simulation per cell, through the sweep layer's host path (so
    ``run_sweep`` sees a populated report)."""
    from .sweep import run_host_sweep
    from .vec_engine import empty_report
    cells, b = build_cells(
        seeds=seeds, n_nodes=n_nodes, n_objects=n_objects,
        write_bw=write_bw, link_bw=link_bw, hop_latency_s=hop_latency_s,
        n_replicas=n_replicas, quorum=quorum,
        placement_weight=placement_weight, offline_node=offline_node,
        mean_gap_s=mean_gap_s, size_mb=size_mb, fault_plan=fault_plan,
        retry=retry, timeout_s=timeout_s, workload=workload)
    if b == 0:
        out = empty_storage_outputs(
            n_nodes, faulted=fault_plan is not None
            or np.isfinite(timeout_s))
        del out["iterations"]                    # the vec loop's counter
        return (out, empty_report(donate=False)) if with_report else out

    def run_cell(i: int):
        sim = backend.make_simulation()
        broker = StorageBroker(sim, cells[i], n_replicas, quorum)
        sim.run()
        assert broker.committed == int(np.sum(broker.dst >= 0)), \
            "storage: lost OBJECT_COMMITs"
        return dict(finish=broker.finish, dst=broker.dst,
                    n_ok=broker.n_ok, killed=broker.killed,
                    repaired=broker.repaired)

    rows, report = run_host_sweep(run_cell, b, chunk_size=chunk_size)
    out = summarize({k: np.stack([r[k] for r in rows]) for k in rows[0]},
                    cells)
    return (out, report) if with_report else out
