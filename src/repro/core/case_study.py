"""The paper's §6 case study, as a reusable scenario builder.

Datacenter: 4 homogeneous hosts, 2 racks, ToR switches + 1 aggregate switch
(Figure 5a). Workflow: 2-task chain T0 → T1 (Figure 5c). Virtualization
configurations (Figure 5b): V = VM on host, C = container on host,
N = container nested in VM (7G nesting, C1).  Parameters per Table 3.

Placement configurations:
  I   — T0,T1 co-located on one guest (0 hops),
  II  — same rack, different hosts (1 hop  = 2 links),
  III — different racks (2 hops = 4 links).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .backend import SimBackend, get_backend, scenario
from .datacenter import Broker, Datacenter
from .engine import Simulation
from .entities import Container, GuestEntity, Host, Vm
from .network import NetworkTopology, theoretical_makespan
from .scheduler import CloudletSchedulerTimeShared
from .workflow import NetworkCloudlet, chain_dag

# Table 3 constants
MIPS = 7800.0                     # m7g.medium: 2.6 GHz × IPC 3 (Eq. 1)
BW = 1e9                          # 1 Gb/s everywhere
O_V, O_C = 5.0, 3.0               # virtualization overheads (s)
L_TASK = 10000.0                  # MI per task
PAYLOAD_SMALL = 1.0               # 1 byte
PAYLOAD_BIG = 1e9                 # 1 GB
ARRIVAL_RATE = 1.0 / 2.564        # Exp(2.564) mean inter-arrival


@dataclass
class CaseStudyResult:
    makespans: List[float]
    theoretical: float
    virt: str
    placement: str
    payload: float


def _mk_guest(virt: str, overhead_on: bool) -> Tuple[GuestEntity, Optional[Vm]]:
    """Build one guest of configuration V/C/N; returns (leaf_guest, outer_vm)."""
    ov = (O_V if overhead_on else 0.0)
    oc = (O_C if overhead_on else 0.0)
    if virt == "V":
        return Vm(CloudletSchedulerTimeShared(), num_pes=1, mips=MIPS,
                  ram=4096, bw=BW, virt_overhead=ov), None
    if virt == "C":
        return Container(CloudletSchedulerTimeShared(), num_pes=1, mips=MIPS,
                         ram=2048, bw=BW, virt_overhead=oc), None
    if virt == "N":   # container nested inside a VM: O_N = O_V + O_C (C4)
        outer = Vm(CloudletSchedulerTimeShared(), num_pes=1, mips=MIPS,
                   ram=4096, bw=BW, virt_overhead=ov)
        inner = Container(CloudletSchedulerTimeShared(), num_pes=1, mips=MIPS,
                          ram=2048, bw=BW, virt_overhead=oc)
        return inner, outer
    raise ValueError(virt)


def build_datacenter(sim: Simulation) -> Tuple[Datacenter, List[Host]]:
    hosts = [Host(num_pes=4, mips=MIPS, ram=65536, bw=BW, guest_scheduler="time",
                  name=f"h{i}") for i in range(4)]
    topo = NetworkTopology(link_bw=BW)
    topo.add_rack(0, hosts[:2])
    topo.add_rack(1, hosts[2:])
    dc = Datacenter(sim, hosts, topology=topo)
    return dc, hosts


PLACEMENTS = {"I": (0, 0), "II": (0, 1), "III": (0, 2)}   # host idx for T0, T1
HOPS = {"I": 0, "II": 1, "III": 2}                        # Eq.(2) networkHops


def cell_overhead(virt: str, overhead_on: bool = True) -> float:
    """Composed virtualization overhead O_α of one Figure-5 cell (C4:
    nesting composes, O_N = O_V + O_C). Shared by the OO and vec paths."""
    return {"V": O_V, "C": O_C, "N": O_V + O_C}[virt] if overhead_on else 0.0


def cell_theoretical(virt: str, placement: str, payload: float,
                     overhead_on: bool = True) -> float:
    """Eq.(2) analytic makespan for one case-study grid cell."""
    return theoretical_makespan([L_TASK, L_TASK], MIPS,
                                cell_overhead(virt, overhead_on),
                                HOPS[placement], payload, BW)


@scenario("case_study", backends=("legacy", "oo"))
def _case_study_scenario(backend: SimBackend, **kw) -> "CaseStudyResult":
    # Event-driven reference path; the ``vec`` implementation (SoA DAG
    # engine under jit/vmap) is registered by ``repro.core.vec_workflow``.
    return _run_case_study_on(backend.make_simulation(), **kw)


def run_case_study(*, backend: str = "oo", virt: str = "V",
                   placement: str = "II", payload: float = PAYLOAD_BIG,
                   activations: int = 1, overhead_on: bool = True,
                   seed: int = 42) -> CaseStudyResult:
    """Simulate the case study; return per-activation makespans + Eq.(2)
    value. Engine selection goes through the SimBackend substrate:
    ``oo``/``legacy`` run the event kernels; ``vec`` runs the vectorized
    DAG engine (``repro.core.vec_workflow``) — bit-identical on
    deterministic single-activation chains, and it additionally accepts
    sequences for ``virt``/``placement``/``payload``/``seed`` to run a
    whole grid of cells in one compiled vmap call."""
    return get_backend(backend).run_scenario(
        "case_study", virt=virt, placement=placement, payload=payload,
        activations=activations, overhead_on=overhead_on, seed=seed)


def _run_case_study_on(sim: Simulation, *, virt: str = "V",
                       placement: str = "II", payload: float = PAYLOAD_BIG,
                       activations: int = 1, overhead_on: bool = True,
                       seed: int = 42) -> CaseStudyResult:
    dc, hosts = build_datacenter(sim)
    broker = Broker(sim, dc)

    h0, h1 = PLACEMENTS[placement]
    guests: List[GuestEntity] = []
    for hidx in ((h0,) if placement == "I" else (h0, h1)):
        leaf, outer = _mk_guest(virt, overhead_on)
        if outer is not None:
            broker.add_guest(outer, on_host=hosts[hidx])
            broker.add_guest(leaf, on_guest=outer)
        else:
            broker.add_guest(leaf, on_host=hosts[hidx])
        guests.append(leaf)
    g0 = guests[0]
    g1 = guests[0] if placement == "I" else guests[1]

    rng = random.Random(seed)
    t = 0.0
    dags: List[List[NetworkCloudlet]] = []
    for a in range(activations):
        if a > 0:
            t += rng.expovariate(ARRIVAL_RATE)
        dag = chain_dag([L_TASK, L_TASK], payload)
        for cl in dag:
            cl.activation_id = a
            cl.submit_time = t
        broker.submit(dag[0], g0, at=t)
        broker.submit(dag[1], g1, at=t)
        dags.append(dag)

    sim.run()

    makespans = []
    for dag in dags:
        start = min(cl.submit_time for cl in dag)
        end = max(cl.finish_time for cl in dag)
        assert end >= 0, "workflow did not complete"
        makespans.append(end - start)

    theo = cell_theoretical(virt, placement, payload, overhead_on)
    return CaseStudyResult(makespans, theo, virt, placement, payload)
