"""Workflow applications — networked cloudlets (NetworkCloudSim, rewritten).

A ``NetworkCloudlet`` is a sequence of stages (paper §2, §4.5):

  EXEC(length MI)   — compute, like a traditional cloudlet stage;
  SEND(peer, bytes) — emit a payload to a peer cloudlet (non-blocking);
  RECV(peer)        — block until the peer's payload arrives.

7G fixes reproduced here (paper §4.5): stages are defined in **MI** (not
milliseconds) so they obey the same execution model as plain cloudlets;
payload sizes are **converted to bits** for transmission time; deadlines are
actually *checked* (``deadline``/``missed_deadline``); and the whole thing is
driven through Algorithm 1's handler methods rather than a forked scheduler —
so plain and networked cloudlets coexist in one ``CloudletScheduler``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from .entities import Cloudlet, CloudletStatus
from .network import Packet


class StageKind(enum.Enum):
    EXEC = enum.auto()
    SEND = enum.auto()
    RECV = enum.auto()


@dataclass
class Stage:
    kind: StageKind
    length: float = 0.0            # MI, for EXEC
    peer: int = -1                 # peer cloudlet id, for SEND/RECV
    payload_bytes: float = 0.0     # for SEND
    done: bool = False


class NetworkCloudlet(Cloudlet):
    """Cloudlet composed of EXEC/SEND/RECV stages.

    Implements Algorithm 1's handler methods only — the scheduling loop
    itself is untouched (the 7G template property).
    """

    def __init__(self, stages: List[Stage], pes: int = 1, *,
                 deadline: float = float("inf"), user_id: int = -1):
        total = sum(s.length for s in stages if s.kind == StageKind.EXEC)
        super().__init__(length=total, pes=pes, user_id=user_id)
        self.stages = stages
        self.stage_idx = 0
        self.deadline = deadline
        self.missed_deadline = False
        self.send_fn: Optional[Callable[[Packet, float], None]] = None
        self._arrived: Dict[int, bool] = {}      # peer id -> payload arrived
        self.activation_id = -1                  # which DAG activation I belong to

    # -- wiring ----------------------------------------------------------------
    def attach_transport(self, send_fn: Callable[[Packet, float], None]) -> None:
        self.send_fn = send_fn

    def deliver(self, pkt: Packet, now: float) -> None:
        """Called by the datacenter when a packet for me arrives."""
        self._arrived[pkt.src_cloudlet] = True

    # -- helpers ----------------------------------------------------------------
    def _stage(self) -> Optional[Stage]:
        return self.stages[self.stage_idx] if self.stage_idx < len(self.stages) else None

    def _advance_nonblocking(self, now: float) -> None:
        """Complete SEND stages and satisfied RECVs without consuming compute."""
        while (st := self._stage()) is not None:
            if st.kind == StageKind.SEND:
                if self.send_fn is None:
                    raise RuntimeError("NetworkCloudlet used without transport")
                self.send_fn(Packet(src_cloudlet=self.id, dst_cloudlet=st.peer,
                                    payload_bytes=st.payload_bytes,
                                    src_guest=self.guest, sent_at=now), now)
                st.done = True
                self.stage_idx += 1
            elif st.kind == StageKind.RECV and self._arrived.get(st.peer, False):
                st.done = True
                self.stage_idx += 1
            else:
                break

    # -- CPU demand: blocked (RECV) / instant (SEND) stages consume no share.
    def wants_cpu(self, now: float) -> bool:
        st = self._stage()
        return st is not None and st.kind == StageKind.EXEC

    # -- handler 1: progress update ----------------------------------------------
    def update_progress(self, time_span: float, alloc_mips: float, now: float) -> None:
        # NOTE: progress applies only to the stage that was active at window
        # start; a RECV satisfied by a packet *at* ``now`` unblocks after the
        # window, never retroactively earning the waited time as compute.
        st = self._stage()
        if st is not None and st.kind == StageKind.EXEC:
            before = sum(s.length for s in self.stages[: self.stage_idx]
                         if s.kind == StageKind.EXEC)
            executed_in_stage = self.length_so_far - before
            grow = time_span * alloc_mips
            room = st.length - executed_in_stage
            step = min(grow, room)
            self.length_so_far += step
            if step >= room - 1e-9:
                st.done = True
                self.stage_idx += 1
        self._advance_nonblocking(now)

    # -- handler 2: stop condition ---------------------------------------------
    def is_finished(self) -> bool:
        return self.stage_idx >= len(self.stages)

    # -- finish hook: deadlines are *checked*, not just stored (7G §4.5) --------
    def on_finished(self, now: float) -> None:
        self.check_deadline(now)

    # -- next-event estimation ----------------------------------------------------
    def estimate_finish(self, now: float, alloc_mips: float) -> float:
        st = self._stage()
        if st is None:
            return now
        if st.kind == StageKind.RECV:
            return float("inf")                 # woken by packet arrival event
        if st.kind == StageKind.SEND:
            return now                          # resolves immediately on update
        if alloc_mips <= 0:
            return float("inf")
        before = sum(s.length for s in self.stages[: self.stage_idx]
                     if s.kind == StageKind.EXEC)
        executed_in_stage = self.length_so_far - before
        # Remaining EXEC work from here to the next blocking stage.
        remaining = st.length - executed_in_stage
        return now + max(remaining, 0.0) / alloc_mips

    def check_deadline(self, now: float) -> None:
        if now - self.submit_time > self.deadline:
            self.missed_deadline = True


# ---------------------------------------------------------------------------
# DAG construction helpers (the case study's T0 → T1 chain, and general DAGs)
# ---------------------------------------------------------------------------

def chain_dag(lengths_mi: List[float], payload_bytes: float,
              deadline: float = float("inf")) -> List[NetworkCloudlet]:
    """Build a linear DAG T0 → T1 → … with one payload per edge."""
    cls: List[NetworkCloudlet] = []
    for L in lengths_mi:
        cls.append(NetworkCloudlet([Stage(StageKind.EXEC, length=L)],
                                   deadline=deadline))
    for up, down in zip(cls[:-1], cls[1:]):
        up.stages.append(Stage(StageKind.SEND, peer=down.id,
                               payload_bytes=payload_bytes))
        up.length = sum(s.length for s in up.stages if s.kind == StageKind.EXEC)
        down.stages.insert(0, Stage(StageKind.RECV, peer=up.id))
    return cls


def generic_dag(nodes: List[float], edges: List[tuple],
                payload_bytes: float) -> List[NetworkCloudlet]:
    """Build a DAG from (src_idx, dst_idx) edges; each node is an EXEC length."""
    cls = [NetworkCloudlet([Stage(StageKind.EXEC, length=L)]) for L in nodes]
    for s_i, d_i in edges:
        cls[s_i].stages.append(Stage(StageKind.SEND, peer=cls[d_i].id,
                                     payload_bytes=payload_bytes))
        cls[d_i].stages.insert(0, Stage(StageKind.RECV, peer=cls[s_i].id))
    for c in cls:
        c.length = sum(s.length for s in c.stages if s.kind == StageKind.EXEC)
    return cls


def _normalize_guests(guest_mips, guest_pes, guest_overhead, guest_bw,
                      host_of_guest, rack_of_host, link_bw):
    """Fill the optional guest/topology arguments' documented defaults —
    shared by the vec and OO ``workflow_batch`` handlers."""
    G = len(guest_mips)
    guest_pes = guest_pes if guest_pes is not None else [1.0] * G
    guest_overhead = (guest_overhead if guest_overhead is not None
                      else [0.0] * G)
    guest_bw = guest_bw if guest_bw is not None else [link_bw] * G
    host_of_guest = (host_of_guest if host_of_guest is not None
                     else list(range(G)))
    rack_of_host = (rack_of_host if rack_of_host is not None
                    else [0] * (max(host_of_guest) + 1))
    return guest_pes, guest_overhead, guest_bw, host_of_guest, rack_of_host


def _workflow_batch_build(nodes, edges, payload, guest_of, guest_mips,
                          guest_pes, guest_overhead, guest_bw, host_of_guest,
                          rack_of_host, link_bw, switch_latency, activations,
                          seed, arrival_rate, deadline):
    """Template DAGs + per-cell (payload, seed) broadcast for one grid."""
    from .vec_workflow import arrival_times, build_spec
    payloads = np.atleast_1d(np.asarray(payload, np.float64))
    seeds = np.atleast_1d(np.asarray(seed, np.int64))
    B = int(np.broadcast_shapes(payloads.shape, seeds.shape)[0])
    payloads = np.broadcast_to(payloads, (B,))
    seeds = np.broadcast_to(seeds, (B,))
    # Callers run _normalize_guests first; all guest args arrive filled.
    specs, arrivals, dag_lists = [], [], []
    for b in range(B):
        arr = arrival_times(activations, int(seeds[b]), arrival_rate)
        dags = [generic_dag(list(nodes), list(edges), float(payloads[b]))
                for _ in range(activations)]
        if deadline is not None:
            for dag in dags:
                for cl in dag:
                    cl.deadline = deadline
        gof = [int(guest_of[i]) for _ in range(activations)
               for i in range(len(nodes))]
        specs.append(build_spec(
            dags, gof, arr, guest_mips=guest_mips, guest_pes=guest_pes,
            guest_overhead=guest_overhead, guest_bw=guest_bw,
            host_of_guest=host_of_guest, rack_of_host=rack_of_host,
            link_bw=link_bw, switch_latency=switch_latency))
        arrivals.append(arr)
        dag_lists.append(dags)
    return specs, arrivals, dag_lists, B


def _workflow_result(finish, arrivals, activations, n_nodes, submit, deadline):
    """Per-activation makespans + deadline misses from flat finish times."""
    B = finish.shape[0]
    makespans = np.empty((B, activations))
    for b in range(B):
        for a in range(activations):
            seg = finish[b, a * n_nodes:(a + 1) * n_nodes]
            makespans[b, a] = np.max(seg) - arrivals[b][a]
    # A task that never finishes (deadlocked DAG) has no finish-time check
    # in the OO engine either — both engines report missed=False for it.
    missed = np.isfinite(finish) & (
        (finish - submit) > (np.inf if deadline is None else deadline))
    return makespans, missed



def _workflow_batch_oo_impl(backend, *, nodes, edges, payload, guest_of,
                            guest_mips, guest_pes, guest_overhead, guest_bw,
                            host_of_guest, rack_of_host, link_bw,
                            switch_latency, activations, seed, arrival_rate,
                            deadline):
    """Reference semantics for ``workflow_batch``: loop the OO event engine
    over every cell (what ``vec_workflow``'s engine replaces with one vmap
    call).  Registered in :mod:`repro.core.vec_workflow`, which owns the
    shared cell builders."""
    from .datacenter import Broker, Datacenter
    from .entities import Host, Vm
    from .network import NetworkTopology
    from .scheduler import CloudletSchedulerTimeShared

    specs, all_arrivals, dag_lists, B = _workflow_batch_build(
        nodes, edges, payload, guest_of, guest_mips, guest_pes,
        guest_overhead, guest_bw, host_of_guest, rack_of_host, link_bw,
        switch_latency, activations, seed, arrival_rate, deadline)
    n_nodes, G = len(nodes), len(guest_mips)
    n_hosts = len(rack_of_host)
    finish = np.full((B, n_nodes * activations), np.inf)
    missed = np.zeros((B, n_nodes * activations), bool)
    for b in range(B):
        sim = backend.make_simulation()
        # Hosts sized to grant every resident guest its full MIPS (the vec
        # path's static-granted contract).
        hosts = []
        for h in range(n_hosts):
            resident = [g for g in range(G) if host_of_guest[g] == h]
            pes_needed = max(int(sum(guest_pes[g] for g in resident)), 1)
            mips = max([guest_mips[g] for g in resident], default=1000.0)
            hosts.append(Host(num_pes=pes_needed, mips=mips, ram=1e12,
                              bw=1e18, guest_scheduler="time", name=f"h{h}"))
        topo = NetworkTopology(link_bw=link_bw, switch_latency=switch_latency)
        for r in sorted(set(rack_of_host)):
            topo.add_rack(r, [hosts[h] for h in range(n_hosts)
                              if rack_of_host[h] == r])
        dc = Datacenter(sim, hosts, topology=topo)
        broker = Broker(sim, dc)
        guests = []
        for g in range(G):
            vm = Vm(CloudletSchedulerTimeShared(), num_pes=int(guest_pes[g]),
                    mips=float(guest_mips[g]), ram=1.0, bw=float(guest_bw[g]),
                    virt_overhead=float(guest_overhead[g]))
            broker.add_guest(vm, on_host=hosts[host_of_guest[g]])
            guests.append(vm)
        for a, dag in enumerate(dag_lists[b]):
            t = all_arrivals[b][a]
            for i, cl in enumerate(dag):
                cl.activation_id = a
                broker.submit(cl, guests[int(guest_of[i])], at=t)
        sim.run()
        for ti, cl in enumerate(cl for dag in dag_lists[b] for cl in dag):
            finish[b, ti] = cl.finish_time if cl.finish_time >= 0 else np.inf
            missed[b, ti] = cl.missed_deadline
    submit = np.stack([np.asarray(sp.submit) for sp in specs])
    makespans, _ = _workflow_result(finish, all_arrivals, activations,
                                    n_nodes, submit, deadline)
    return dict(finish=finish, makespans=makespans, missed_deadline=missed,
                iterations=np.zeros((B,), np.int32))
