"""Workflow applications — networked cloudlets (NetworkCloudSim, rewritten).

A ``NetworkCloudlet`` is a sequence of stages (paper §2, §4.5):

  EXEC(length MI)   — compute, like a traditional cloudlet stage;
  SEND(peer, bytes) — emit a payload to a peer cloudlet (non-blocking);
  RECV(peer)        — block until the peer's payload arrives.

7G fixes reproduced here (paper §4.5): stages are defined in **MI** (not
milliseconds) so they obey the same execution model as plain cloudlets;
payload sizes are **converted to bits** for transmission time; deadlines are
actually *checked* (``deadline``/``missed_deadline``); and the whole thing is
driven through Algorithm 1's handler methods rather than a forked scheduler —
so plain and networked cloudlets coexist in one ``CloudletScheduler``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .entities import Cloudlet, CloudletStatus
from .network import Packet


class StageKind(enum.Enum):
    EXEC = enum.auto()
    SEND = enum.auto()
    RECV = enum.auto()


@dataclass
class Stage:
    kind: StageKind
    length: float = 0.0            # MI, for EXEC
    peer: int = -1                 # peer cloudlet id, for SEND/RECV
    payload_bytes: float = 0.0     # for SEND
    done: bool = False


class NetworkCloudlet(Cloudlet):
    """Cloudlet composed of EXEC/SEND/RECV stages.

    Implements Algorithm 1's handler methods only — the scheduling loop
    itself is untouched (the 7G template property).
    """

    def __init__(self, stages: List[Stage], pes: int = 1, *,
                 deadline: float = float("inf"), user_id: int = -1):
        total = sum(s.length for s in stages if s.kind == StageKind.EXEC)
        super().__init__(length=total, pes=pes, user_id=user_id)
        self.stages = stages
        self.stage_idx = 0
        self.deadline = deadline
        self.missed_deadline = False
        self.send_fn: Optional[Callable[[Packet, float], None]] = None
        self._arrived: Dict[int, bool] = {}      # peer id -> payload arrived
        self.activation_id = -1                  # which DAG activation I belong to

    # -- wiring ----------------------------------------------------------------
    def attach_transport(self, send_fn: Callable[[Packet, float], None]) -> None:
        self.send_fn = send_fn

    def deliver(self, pkt: Packet, now: float) -> None:
        """Called by the datacenter when a packet for me arrives."""
        self._arrived[pkt.src_cloudlet] = True

    # -- helpers ----------------------------------------------------------------
    def _stage(self) -> Optional[Stage]:
        return self.stages[self.stage_idx] if self.stage_idx < len(self.stages) else None

    def _advance_nonblocking(self, now: float) -> None:
        """Complete SEND stages and satisfied RECVs without consuming compute."""
        while (st := self._stage()) is not None:
            if st.kind == StageKind.SEND:
                if self.send_fn is None:
                    raise RuntimeError("NetworkCloudlet used without transport")
                self.send_fn(Packet(src_cloudlet=self.id, dst_cloudlet=st.peer,
                                    payload_bytes=st.payload_bytes,
                                    src_guest=self.guest, sent_at=now), now)
                st.done = True
                self.stage_idx += 1
            elif st.kind == StageKind.RECV and self._arrived.get(st.peer, False):
                st.done = True
                self.stage_idx += 1
            else:
                break

    # -- CPU demand: blocked (RECV) / instant (SEND) stages consume no share.
    def wants_cpu(self, now: float) -> bool:
        st = self._stage()
        return st is not None and st.kind == StageKind.EXEC

    # -- handler 1: progress update ----------------------------------------------
    def update_progress(self, time_span: float, alloc_mips: float, now: float) -> None:
        # NOTE: progress applies only to the stage that was active at window
        # start; a RECV satisfied by a packet *at* ``now`` unblocks after the
        # window, never retroactively earning the waited time as compute.
        st = self._stage()
        if st is not None and st.kind == StageKind.EXEC:
            before = sum(s.length for s in self.stages[: self.stage_idx]
                         if s.kind == StageKind.EXEC)
            executed_in_stage = self.length_so_far - before
            grow = time_span * alloc_mips
            room = st.length - executed_in_stage
            step = min(grow, room)
            self.length_so_far += step
            if step >= room - 1e-9:
                st.done = True
                self.stage_idx += 1
        self._advance_nonblocking(now)

    # -- handler 2: stop condition ---------------------------------------------
    def is_finished(self) -> bool:
        return self.stage_idx >= len(self.stages)

    # -- finish hook: deadlines are *checked*, not just stored (7G §4.5) --------
    def on_finished(self, now: float) -> None:
        self.check_deadline(now)

    # -- next-event estimation ----------------------------------------------------
    def estimate_finish(self, now: float, alloc_mips: float) -> float:
        st = self._stage()
        if st is None:
            return now
        if st.kind == StageKind.RECV:
            return float("inf")                 # woken by packet arrival event
        if st.kind == StageKind.SEND:
            return now                          # resolves immediately on update
        if alloc_mips <= 0:
            return float("inf")
        before = sum(s.length for s in self.stages[: self.stage_idx]
                     if s.kind == StageKind.EXEC)
        executed_in_stage = self.length_so_far - before
        # Remaining EXEC work from here to the next blocking stage.
        remaining = st.length - executed_in_stage
        return now + max(remaining, 0.0) / alloc_mips

    def check_deadline(self, now: float) -> None:
        if now - self.submit_time > self.deadline:
            self.missed_deadline = True


# ---------------------------------------------------------------------------
# DAG construction helpers (the case study's T0 → T1 chain, and general DAGs)
# ---------------------------------------------------------------------------

def chain_dag(lengths_mi: List[float], payload_bytes: float,
              deadline: float = float("inf")) -> List[NetworkCloudlet]:
    """Build a linear DAG T0 → T1 → … with one payload per edge."""
    cls: List[NetworkCloudlet] = []
    for L in lengths_mi:
        cls.append(NetworkCloudlet([Stage(StageKind.EXEC, length=L)],
                                   deadline=deadline))
    for up, down in zip(cls[:-1], cls[1:]):
        up.stages.append(Stage(StageKind.SEND, peer=down.id,
                               payload_bytes=payload_bytes))
        up.length = sum(s.length for s in up.stages if s.kind == StageKind.EXEC)
        down.stages.insert(0, Stage(StageKind.RECV, peer=up.id))
    return cls


def generic_dag(nodes: List[float], edges: List[tuple],
                payload_bytes: float) -> List[NetworkCloudlet]:
    """Build a DAG from (src_idx, dst_idx) edges; each node is an EXEC length."""
    cls = [NetworkCloudlet([Stage(StageKind.EXEC, length=L)]) for L in nodes]
    for s_i, d_i in edges:
        cls[s_i].stages.append(Stage(StageKind.SEND, peer=cls[d_i].id,
                                     payload_bytes=payload_bytes))
        cls[d_i].stages.insert(0, Stage(StageKind.RECV, peer=cls[s_i].id))
    for c in cls:
        c.length = sum(s.length for s in c.stages if s.kind == StageKind.EXEC)
    return cls
