"""Vectorized Algorithm 1 — the paper's scheduler life-cycle as JAX SoA.

CloudSim's ``CloudletScheduler`` advances each cloudlet with a Python/Java
``for`` loop per scheduler per event; here all guests × all cloudlets advance
in one fused masked-vector pass and "next event" is a masked min reduction
(``repro.kernels.ops``), with the whole simulation (Algorithm 1 lines 1–23,
iterated to completion) inside a single ``lax.while_loop`` — the substrate
conventions live in :mod:`repro.core.vec_engine`.

Semantics exactly match ``CloudletSchedulerTimeShared`` /
``CloudletSchedulerSpaceShared`` (asserted by tests against the OO engine):

  time-shared : per-guest capacity = granted / max(Σ active pes, num_pes),
                every submitted cloudlet runs immediately;
  space-shared: cloudlets admitted FIFO while free PEs remain, each running
                at (granted / num_pes) · pes.

State layout (G guests × C cloudlet slots, padded with zeros):
  length[G,C]   total MI          done[G,C]    MI executed
  pes[G,C]      PEs requested     submit[G,C]  submission time
  finish[G,C]   finish time (inf until done)
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ops import masked_min
from .backend import SimBackend, scenario
from .vec_engine import BatchPlan, Loop, VecEngine, make_batch_entry

INF = jnp.inf


class VecSchedState(NamedTuple):
    length: jax.Array      # [G, C] total MI per cloudlet (0 => empty slot)
    done: jax.Array        # [G, C] MI executed so far
    pes: jax.Array         # [G, C] PEs requested
    submit: jax.Array      # [G, C] submission times
    finish: jax.Array      # [G, C] finish times (inf = not finished)
    now: jax.Array         # [] current simulation time


def make_state(length, pes, submit) -> VecSchedState:
    length = jnp.asarray(length, jnp.float64)
    return VecSchedState(
        length=length,
        done=jnp.zeros_like(length),
        pes=jnp.asarray(pes, jnp.float64),
        submit=jnp.asarray(submit, jnp.float64),
        finish=jnp.full_like(length, INF),
        now=jnp.asarray(0.0, jnp.float64),
    )


def _alloc_mips(state: VecSchedState, guest_mips, guest_pes, mode: str):
    """Per-cloudlet allocated MIPS under the given sharing mode. [G, C]."""
    arrived = state.submit <= state.now
    unfinished = state.done < state.length - 1e-9
    valid = state.length > 0
    active = arrived & unfinished & valid                      # [G, C]
    if mode == "time":
        req_pes = jnp.sum(jnp.where(active, state.pes, 0.0), axis=1)    # [G]
        denom = jnp.maximum(req_pes, guest_pes)
        capacity = jnp.where(denom > 0, guest_mips * guest_pes / denom, 0.0)
        return jnp.where(active, capacity[:, None] * state.pes, 0.0), active
    elif mode == "space":
        # FIFO admission by slot order: run while cumulative PEs fit.
        cum = jnp.cumsum(jnp.where(active, state.pes, 0.0), axis=1)
        admitted = active & (cum <= guest_pes[:, None] + 1e-9)
        return jnp.where(admitted, guest_mips[:, None] * state.pes, 0.0), admitted
    raise ValueError(mode)


def _next_event_time(state: VecSchedState, alloc, use_pallas: bool) -> jax.Array:
    """min over (est. finish of running cloudlets, future submissions) —
    through :func:`repro.kernels.ops.masked_min` (exact minima on both the
    jnp and Pallas paths, so results are bit-identical)."""
    remaining = jnp.maximum(state.length - state.done, 0.0)
    est = jnp.where(alloc > 0, state.now + remaining / jnp.maximum(alloc, 1e-30), INF)
    future = jnp.where(state.submit > state.now, state.submit, INF)
    return masked_min(jnp.concatenate([est.reshape(-1), future.reshape(-1)]),
                      use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas"))
def step(state: VecSchedState, guest_mips, guest_pes, mode: str,
         use_pallas: bool = False) -> Tuple[VecSchedState, jax.Array]:
    """One Algorithm-1 pass for ALL guests: advance to the next event.

    Returns (new_state, next_time). next_time == inf ⇒ simulation complete.
    """
    alloc, _ = _alloc_mips(state, guest_mips, guest_pes, mode)
    t_next = _next_event_time(state, alloc, use_pallas)           # lines 17-23
    span = jnp.where(jnp.isfinite(t_next), t_next - state.now, 0.0)
    done = jnp.minimum(state.done + span * alloc, state.length)   # lines 2-5
    newly = (done >= state.length - 1e-9) & (state.done < state.length - 1e-9) \
            & (state.length > 0)                                  # lines 6-9
    finish = jnp.where(newly, t_next, state.finish)
    new = state._replace(done=done, finish=finish,
                         now=jnp.where(jnp.isfinite(t_next), t_next, state.now))
    return new, t_next


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas"))
def simulate(state: VecSchedState, guest_mips, guest_pes, mode: str,
             use_pallas: bool = False) -> VecSchedState:
    """Run Algorithm 1 to completion inside one lax.while_loop."""

    def cond(carry):
        st, t = carry
        return jnp.isfinite(t)

    def body(carry):
        st, _ = carry
        return step(st, guest_mips, guest_pes, mode, use_pallas)

    st, t0 = step(state, guest_mips, guest_pes, mode, use_pallas)
    st, _ = jax.lax.while_loop(cond, body, (st, t0))
    return st


def _canonical_order(submit):
    """Space-shared FIFO is defined by *arrival* order: canonicalize slot
    order to (submit time, slot index) per guest; returns (order, inverse)."""
    import numpy as np
    order = np.argsort(submit + np.arange(submit.shape[-1]) * 1e-12, axis=-1,
                       kind="stable")
    return order, np.argsort(order, axis=-1, kind="stable")


def simulate_batch(length, pes, submit, guest_mips, guest_pes,
                   mode: str = "time", *, use_pallas: bool | str = False):
    """Convenience wrapper: returns finish times [G, C] (inf for empty slots).

    Runs under x64 so event times match the OO engine's doubles bit-for-bit
    (enabled locally — the model stack elsewhere stays on default f32/bf16).
    All guests share one global event clock, exactly like the OO kernel —
    for a *batch of independent scheduler problems* (cells that may be
    chunked/sharded without changing a bit) use :func:`simulate_cells`.
    """
    import numpy as np
    from ..kernels.ops import resolve_use_pallas
    use_pallas = resolve_use_pallas(use_pallas)
    length = np.asarray(length, np.float64)
    pes = np.asarray(pes, np.float64)
    submit = np.asarray(submit, np.float64)
    order, inv = _canonical_order(submit)
    g_idx = np.arange(length.shape[0])[:, None]
    with jax.experimental.enable_x64():
        guest_mips = jnp.asarray(guest_mips, jnp.float64)
        guest_pes = jnp.asarray(guest_pes, jnp.float64)
        st = simulate(make_state(length[g_idx, order], pes[g_idx, order],
                                 submit[g_idx, order]),
                      guest_mips, guest_pes, mode, use_pallas)
        return np.asarray(st.finish)[g_idx, inv]


# -- multi-cell batched entry (a VecEngine definition) -------------------------

class _CellStatics(NamedTuple):
    mode: str
    use_pallas: bool


def _cells_build(params, statics: _CellStatics, ops) -> Loop:
    """One complete [G, C] scheduler problem per cell, on its own event
    clock (cells never interact — chunking/sharding the cell axis is
    bit-identical to the monolithic dispatch, unlike guests *within* a
    cell, which share the global clock)."""
    length, pes, submit, gmips, gpes = params
    run = functools.partial(step, guest_mips=gmips, guest_pes=gpes,
                            mode=statics.mode, use_pallas=statics.use_pallas)
    return Loop(
        init=run(make_state(length, pes, submit)),
        cond=lambda c, it: jnp.isfinite(c[1]),
        body=lambda c, it: run(c[0]),
        # One step ran before the loop: count it in the iteration total.
        finalize=lambda c, it: dict(finish=c[0].finish, iterations=it + 1))


CELLS_ENGINE = VecEngine("cloudlet_batch", _cells_build)


def _prepare_cells(length, pes, submit, guest_mips, guest_pes,
                   mode: str = "time", *, use_pallas: bool) -> BatchPlan:
    import numpy as np
    length = np.asarray(length, np.float64)
    pes = np.asarray(pes, np.float64)
    submit = np.asarray(submit, np.float64)
    order, inv = _canonical_order(submit)
    params = (np.take_along_axis(length, order, -1),
              np.take_along_axis(pes, order, -1),
              np.take_along_axis(submit, order, -1),
              np.asarray(guest_mips, np.float64),
              np.asarray(guest_pes, np.float64))
    return BatchPlan(
        params, _CellStatics(mode, bool(use_pallas)),
        # Loop length ≈ events ≈ live cloudlets (+ their submissions).
        predicted_cost=np.count_nonzero(length > 0, axis=(1, 2)) + 1,
        finalize=lambda out: np.take_along_axis(out["finish"], inv, -1))


simulate_cells = make_batch_entry(
    CELLS_ENGINE, _prepare_cells, backends=(), name="simulate_cells", doc="""\
    Batch of independent scheduler cells through the sweep layer.

    ``length``/``pes``/``submit`` are ``[B, G, C]``; ``guest_mips``/
    ``guest_pes`` are ``[B, G]``.  Every cell advances on its own event
    clock (the [G, C] semantics within one cell are exactly
    :func:`simulate_batch`'s).  Returns finish times ``[B, G, C]``; with
    ``with_report=True`` returns ``(finish, SweepReport)``.  Cells are
    bucketed by live-cloudlet count, chunked with donated buffers, and
    sharded across devices — bit-identical to the monolithic dispatch.
    """)


# -- backend substrate handlers ------------------------------------------------

@scenario("cloudlet_batch", backends=("vec",))
def _cloudlet_batch_vec(backend: SimBackend, *, length, pes, submit,
                        guest_mips, guest_pes, mode: str = "time",
                        use_pallas: bool | str = False, **sweep_kw):
    """Finish times via the compiled SoA path: ``[G, C]`` inputs run the
    single-problem global-clock simulator; ``[B, G, C]`` inputs run a batch
    of independent cells through the sweep layer (``chunk_size`` /
    ``devices`` / ``with_report`` accepted)."""
    import numpy as np
    if np.asarray(length).ndim == 3:
        return simulate_cells(length, pes, submit, guest_mips, guest_pes,
                              mode, use_pallas=use_pallas, **sweep_kw)
    return simulate_batch(length, pes, submit, guest_mips, guest_pes, mode,
                          use_pallas=use_pallas)


@scenario("cloudlet_batch", backends=("legacy", "oo"))
def _cloudlet_batch_oo(backend: SimBackend, *, length, pes, submit,
                       guest_mips, guest_pes, mode: str = "time",
                       use_pallas: bool = False):
    """Reference semantics (:func:`repro.core.scheduler
    ._cloudlet_batch_oo_impl`): the OO event engine, per cell.  Sweep
    controls are deliberately *not* accepted — ``backend.run_sweep``'s
    contract is a ``TypeError``, not a silently-dropped report."""
    from .scheduler import _cloudlet_batch_oo_impl
    return _cloudlet_batch_oo_impl(backend, length=length, pes=pes,
                                   submit=submit, guest_mips=guest_mips,
                                   guest_pes=guest_pes, mode=mode)
