"""Vectorized Algorithm 1 — the paper's scheduler life-cycle as JAX SoA.

Beyond-paper contribution: CloudSim's ``CloudletScheduler`` advances each
cloudlet with a Python/Java ``for`` loop per scheduler per event.  On
accelerator-class hardware the idiomatic form is structure-of-arrays: all
guests × all cloudlets advance in one fused masked-vector pass, and the
"next event" is an ``argmin`` reduction instead of a heap walk.  The entire
simulation (lines 1–23 of Algorithm 1, iterated to completion) runs inside a
single ``jax.lax.while_loop`` under ``jax.jit``.

Semantics exactly match ``CloudletSchedulerTimeShared`` /
``CloudletSchedulerSpaceShared`` (asserted by tests against the OO engine):

  time-shared : per-guest capacity = granted / max(Σ active pes, num_pes),
                every submitted cloudlet runs immediately;
  space-shared: cloudlets admitted FIFO while free PEs remain, each running
                at (granted / num_pes) · pes.

State layout (G guests × C cloudlet slots, padded with zeros):
  length[G,C]   total MI          done[G,C]    MI executed
  pes[G,C]      PEs requested     submit[G,C]  submission time
  finish[G,C]   finish time (inf until done)
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .backend import SimBackend, scenario

INF = jnp.inf


class VecSchedState(NamedTuple):
    length: jax.Array      # [G, C] total MI per cloudlet (0 => empty slot)
    done: jax.Array        # [G, C] MI executed so far
    pes: jax.Array         # [G, C] PEs requested
    submit: jax.Array      # [G, C] submission times
    finish: jax.Array      # [G, C] finish times (inf = not finished)
    now: jax.Array         # [] current simulation time


def make_state(length, pes, submit) -> VecSchedState:
    length = jnp.asarray(length, jnp.float64)
    return VecSchedState(
        length=length,
        done=jnp.zeros_like(length),
        pes=jnp.asarray(pes, jnp.float64),
        submit=jnp.asarray(submit, jnp.float64),
        finish=jnp.full_like(length, INF),
        now=jnp.asarray(0.0, jnp.float64),
    )


def _alloc_mips(state: VecSchedState, guest_mips, guest_pes, mode: str):
    """Per-cloudlet allocated MIPS under the given sharing mode. [G, C]."""
    arrived = state.submit <= state.now
    unfinished = state.done < state.length - 1e-9
    valid = state.length > 0
    active = arrived & unfinished & valid                      # [G, C]
    if mode == "time":
        req_pes = jnp.sum(jnp.where(active, state.pes, 0.0), axis=1)    # [G]
        denom = jnp.maximum(req_pes, guest_pes)
        capacity = jnp.where(denom > 0, guest_mips * guest_pes / denom, 0.0)
        return jnp.where(active, capacity[:, None] * state.pes, 0.0), active
    elif mode == "space":
        # FIFO admission by slot order: run while cumulative PEs fit.
        cum = jnp.cumsum(jnp.where(active, state.pes, 0.0), axis=1)
        admitted = active & (cum <= guest_pes[:, None] + 1e-9)
        return jnp.where(admitted, guest_mips[:, None] * state.pes, 0.0), admitted
    raise ValueError(mode)


def _next_event_time(state: VecSchedState, alloc, use_pallas: bool) -> jax.Array:
    """min over (est. finish of running cloudlets, future submissions).

    With ``use_pallas`` the reduction runs through the fused masked
    min/argmin Pallas kernel (``kernels.next_event``, interpret mode on
    CPU); both paths are exact minima, so results are bit-identical.
    """
    remaining = jnp.maximum(state.length - state.done, 0.0)
    est = jnp.where(alloc > 0, state.now + remaining / jnp.maximum(alloc, 1e-30), INF)
    future = jnp.where(state.submit > state.now, state.submit, INF)
    if use_pallas:
        from ..kernels.ops import next_event_op
        cand = jnp.concatenate([est.reshape(-1), future.reshape(-1)])
        t_min, _ = next_event_op(cand, interpret=True)
        return t_min
    return jnp.minimum(jnp.min(est), jnp.min(future))


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas"))
def step(state: VecSchedState, guest_mips, guest_pes, mode: str,
         use_pallas: bool = False) -> Tuple[VecSchedState, jax.Array]:
    """One Algorithm-1 pass for ALL guests: advance to the next event.

    Returns (new_state, next_time). next_time == inf ⇒ simulation complete.
    """
    alloc, _ = _alloc_mips(state, guest_mips, guest_pes, mode)
    t_next = _next_event_time(state, alloc, use_pallas)           # lines 17-23
    span = jnp.where(jnp.isfinite(t_next), t_next - state.now, 0.0)
    done = jnp.minimum(state.done + span * alloc, state.length)   # lines 2-5
    newly = (done >= state.length - 1e-9) & (state.done < state.length - 1e-9) \
            & (state.length > 0)                                  # lines 6-9
    finish = jnp.where(newly, t_next, state.finish)
    new = state._replace(done=done, finish=finish,
                         now=jnp.where(jnp.isfinite(t_next), t_next, state.now))
    return new, t_next


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas"))
def simulate(state: VecSchedState, guest_mips, guest_pes, mode: str,
             use_pallas: bool = False) -> VecSchedState:
    """Run Algorithm 1 to completion inside one lax.while_loop."""

    def cond(carry):
        st, t = carry
        return jnp.isfinite(t)

    def body(carry):
        st, _ = carry
        return step(st, guest_mips, guest_pes, mode, use_pallas)

    st, t0 = step(state, guest_mips, guest_pes, mode, use_pallas)
    st, _ = jax.lax.while_loop(cond, body, (st, t0))
    return st


def simulate_batch(length, pes, submit, guest_mips, guest_pes,
                   mode: str = "time", *, use_pallas: bool = False):
    """Convenience wrapper: returns finish times [G, C] (inf for empty slots).

    Runs under x64 so event times match the OO engine's doubles bit-for-bit
    (enabled locally — the model stack elsewhere stays on default f32/bf16).
    """
    import numpy as np
    length = np.asarray(length, np.float64)
    pes = np.asarray(pes, np.float64)
    submit = np.asarray(submit, np.float64)
    # Space-shared FIFO is defined by *arrival* order: canonicalize slot
    # order to (submit time, slot index) per guest, then un-permute results.
    order = np.argsort(submit + np.arange(submit.shape[1]) * 1e-12, axis=1,
                       kind="stable")
    inv = np.argsort(order, axis=1, kind="stable")
    g_idx = np.arange(length.shape[0])[:, None]
    with jax.experimental.enable_x64():
        guest_mips = jnp.asarray(guest_mips, jnp.float64)
        guest_pes = jnp.asarray(guest_pes, jnp.float64)
        st = simulate(make_state(length[g_idx, order], pes[g_idx, order],
                                 submit[g_idx, order]),
                      guest_mips, guest_pes, mode, use_pallas)
        return np.asarray(st.finish)[g_idx, inv]


# -- backend substrate handlers ------------------------------------------------

@scenario("cloudlet_batch", backends=("vec",))
def _cloudlet_batch_vec(backend: SimBackend, *, length, pes, submit,
                        guest_mips, guest_pes, mode: str = "time",
                        use_pallas: bool = False):
    """Finish times [G, C] via the compiled SoA path."""
    return simulate_batch(length, pes, submit, guest_mips, guest_pes, mode,
                          use_pallas=use_pallas)


@scenario("cloudlet_batch", backends=("legacy", "oo"))
def _cloudlet_batch_oo(backend: SimBackend, *, length, pes, submit,
                       guest_mips, guest_pes, mode: str = "time",
                       use_pallas: bool = False):
    """Finish times [G, C] via the OO engine (reference semantics; inf for
    empty/unfinished slots) — same contract as the vec handler."""
    import numpy as np
    from .datacenter import Broker, Datacenter
    from .entities import Cloudlet, Host, Vm
    from .scheduler import (CloudletSchedulerSpaceShared,
                            CloudletSchedulerTimeShared)
    length = np.asarray(length, np.float64)
    pes = np.asarray(pes, np.float64)
    submit = np.asarray(submit, np.float64)
    G, C = length.shape
    sim = backend.make_simulation()
    hosts = [Host(num_pes=int(guest_pes[g]), mips=float(guest_mips[g]),
                  ram=1e9, bw=1e9) for g in range(G)]
    dc = Datacenter(sim, hosts)
    broker = Broker(sim, dc)
    guests = []
    for g in range(G):
        sch = (CloudletSchedulerTimeShared() if mode == "time"
               else CloudletSchedulerSpaceShared())
        vm = Vm(sch, num_pes=int(guest_pes[g]), mips=float(guest_mips[g]),
                ram=1024, bw=1e9)
        broker.add_guest(vm, on_host=hosts[g])
        guests.append(vm)
    cls = {}
    for t, g, c in sorted((submit[g, c], g, c) for g in range(G)
                          for c in range(C) if length[g, c] > 0):
        cl = Cloudlet(length=float(length[g, c]), pes=int(pes[g, c]))
        cls[(g, c)] = cl
        broker.submit(cl, guests[g], at=float(t))
    sim.run()
    out = np.full((G, C), np.inf)
    for (g, c), cl in cls.items():
        out[g, c] = cl.finish_time if cl.finish_time >= 0 else np.inf
    return out
