"""Vectorized Algorithm 1 — the paper's scheduler life-cycle as JAX SoA.

Beyond-paper contribution: CloudSim's ``CloudletScheduler`` advances each
cloudlet with a Python/Java ``for`` loop per scheduler per event.  On
accelerator-class hardware the idiomatic form is structure-of-arrays: all
guests × all cloudlets advance in one fused masked-vector pass, and the
"next event" is an ``argmin`` reduction instead of a heap walk.  The entire
simulation (lines 1–23 of Algorithm 1, iterated to completion) runs inside a
single ``jax.lax.while_loop`` under ``jax.jit``.

Semantics exactly match ``CloudletSchedulerTimeShared`` /
``CloudletSchedulerSpaceShared`` (asserted by tests against the OO engine):

  time-shared : per-guest capacity = granted / max(Σ active pes, num_pes),
                every submitted cloudlet runs immediately;
  space-shared: cloudlets admitted FIFO while free PEs remain, each running
                at (granted / num_pes) · pes.

State layout (G guests × C cloudlet slots, padded with zeros):
  length[G,C]   total MI          done[G,C]    MI executed
  pes[G,C]      PEs requested     submit[G,C]  submission time
  finish[G,C]   finish time (inf until done)
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .backend import SimBackend, scenario

INF = jnp.inf


class VecSchedState(NamedTuple):
    length: jax.Array      # [G, C] total MI per cloudlet (0 => empty slot)
    done: jax.Array        # [G, C] MI executed so far
    pes: jax.Array         # [G, C] PEs requested
    submit: jax.Array      # [G, C] submission times
    finish: jax.Array      # [G, C] finish times (inf = not finished)
    now: jax.Array         # [] current simulation time


def make_state(length, pes, submit) -> VecSchedState:
    length = jnp.asarray(length, jnp.float64)
    return VecSchedState(
        length=length,
        done=jnp.zeros_like(length),
        pes=jnp.asarray(pes, jnp.float64),
        submit=jnp.asarray(submit, jnp.float64),
        finish=jnp.full_like(length, INF),
        now=jnp.asarray(0.0, jnp.float64),
    )


def _alloc_mips(state: VecSchedState, guest_mips, guest_pes, mode: str):
    """Per-cloudlet allocated MIPS under the given sharing mode. [G, C]."""
    arrived = state.submit <= state.now
    unfinished = state.done < state.length - 1e-9
    valid = state.length > 0
    active = arrived & unfinished & valid                      # [G, C]
    if mode == "time":
        req_pes = jnp.sum(jnp.where(active, state.pes, 0.0), axis=1)    # [G]
        denom = jnp.maximum(req_pes, guest_pes)
        capacity = jnp.where(denom > 0, guest_mips * guest_pes / denom, 0.0)
        return jnp.where(active, capacity[:, None] * state.pes, 0.0), active
    elif mode == "space":
        # FIFO admission by slot order: run while cumulative PEs fit.
        cum = jnp.cumsum(jnp.where(active, state.pes, 0.0), axis=1)
        admitted = active & (cum <= guest_pes[:, None] + 1e-9)
        return jnp.where(admitted, guest_mips[:, None] * state.pes, 0.0), admitted
    raise ValueError(mode)


def _next_event_time(state: VecSchedState, alloc, use_pallas: bool) -> jax.Array:
    """min over (est. finish of running cloudlets, future submissions).

    With ``use_pallas`` the reduction runs through the fused masked
    min/argmin Pallas kernel (``kernels.next_event``, interpret mode on
    CPU); both paths are exact minima, so results are bit-identical.
    """
    remaining = jnp.maximum(state.length - state.done, 0.0)
    est = jnp.where(alloc > 0, state.now + remaining / jnp.maximum(alloc, 1e-30), INF)
    future = jnp.where(state.submit > state.now, state.submit, INF)
    if use_pallas:
        from ..kernels.ops import next_event_op
        cand = jnp.concatenate([est.reshape(-1), future.reshape(-1)])
        t_min, _ = next_event_op(cand)
        return t_min
    return jnp.minimum(jnp.min(est), jnp.min(future))


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas"))
def step(state: VecSchedState, guest_mips, guest_pes, mode: str,
         use_pallas: bool = False) -> Tuple[VecSchedState, jax.Array]:
    """One Algorithm-1 pass for ALL guests: advance to the next event.

    Returns (new_state, next_time). next_time == inf ⇒ simulation complete.
    """
    alloc, _ = _alloc_mips(state, guest_mips, guest_pes, mode)
    t_next = _next_event_time(state, alloc, use_pallas)           # lines 17-23
    span = jnp.where(jnp.isfinite(t_next), t_next - state.now, 0.0)
    done = jnp.minimum(state.done + span * alloc, state.length)   # lines 2-5
    newly = (done >= state.length - 1e-9) & (state.done < state.length - 1e-9) \
            & (state.length > 0)                                  # lines 6-9
    finish = jnp.where(newly, t_next, state.finish)
    new = state._replace(done=done, finish=finish,
                         now=jnp.where(jnp.isfinite(t_next), t_next, state.now))
    return new, t_next


@functools.partial(jax.jit, static_argnames=("mode", "use_pallas"))
def simulate(state: VecSchedState, guest_mips, guest_pes, mode: str,
             use_pallas: bool = False) -> VecSchedState:
    """Run Algorithm 1 to completion inside one lax.while_loop."""

    def cond(carry):
        st, t = carry
        return jnp.isfinite(t)

    def body(carry):
        st, _ = carry
        return step(st, guest_mips, guest_pes, mode, use_pallas)

    st, t0 = step(state, guest_mips, guest_pes, mode, use_pallas)
    st, _ = jax.lax.while_loop(cond, body, (st, t0))
    return st


def simulate_batch(length, pes, submit, guest_mips, guest_pes,
                   mode: str = "time", *, use_pallas: bool | str = False):
    """Convenience wrapper: returns finish times [G, C] (inf for empty slots).

    Runs under x64 so event times match the OO engine's doubles bit-for-bit
    (enabled locally — the model stack elsewhere stays on default f32/bf16).
    All guests share one global event clock, exactly like the OO kernel —
    for a *batch of independent scheduler problems* (cells that may be
    chunked/sharded without changing a bit) use :func:`simulate_cells`.
    """
    import numpy as np
    from ..kernels.ops import resolve_use_pallas
    use_pallas = resolve_use_pallas(use_pallas)
    length = np.asarray(length, np.float64)
    pes = np.asarray(pes, np.float64)
    submit = np.asarray(submit, np.float64)
    # Space-shared FIFO is defined by *arrival* order: canonicalize slot
    # order to (submit time, slot index) per guest, then un-permute results.
    order = np.argsort(submit + np.arange(submit.shape[1]) * 1e-12, axis=1,
                       kind="stable")
    inv = np.argsort(order, axis=1, kind="stable")
    g_idx = np.arange(length.shape[0])[:, None]
    with jax.experimental.enable_x64():
        guest_mips = jnp.asarray(guest_mips, jnp.float64)
        guest_pes = jnp.asarray(guest_pes, jnp.float64)
        st = simulate(make_state(length[g_idx, order], pes[g_idx, order],
                                 submit[g_idx, order]),
                      guest_mips, guest_pes, mode, use_pallas)
        return np.asarray(st.finish)[g_idx, inv]


# -- multi-cell batched entry (the sweep layer's unit of work) -----------------

@functools.lru_cache(maxsize=32)
def _batched_cells(mode: str, use_pallas: bool):
    """Vmapped whole-simulation runner over independent scheduler cells, in
    the sweep layer's single-pytree calling convention.

    Each cell is one complete [G, C] scheduler problem with its own event
    clock (cells never interact), so chunking/sharding the cell axis is
    bit-identical to the monolithic dispatch — unlike guests *within* a
    cell, which share the global clock.  Also counts loop iterations per
    cell for the sweep layer's divergence accounting.
    """
    def one(args):
        length, pes, submit, gmips, gpes = args
        st, t0 = step(make_state(length, pes, submit), gmips, gpes, mode,
                      use_pallas)

        def cond(c):
            return jnp.isfinite(c[1])

        def body(c):
            st, _, it = c
            st2, t2 = step(st, gmips, gpes, mode, use_pallas)
            return st2, t2, it + 1

        st, _, it = jax.lax.while_loop(cond, body,
                                       (st, t0, jnp.asarray(1, jnp.int32)))
        return dict(finish=st.finish, iterations=it)

    return jax.vmap(one)


def simulate_cells(length, pes, submit, guest_mips, guest_pes,
                   mode: str = "time", *, use_pallas: bool | str = False,
                   chunk_size=None, devices=None, donate: bool = True,
                   with_report: bool = False):
    """Batch of independent scheduler cells through the sweep layer.

    ``length``/``pes``/``submit`` are ``[B, G, C]``; ``guest_mips``/
    ``guest_pes`` are ``[B, G]``.  Every cell advances on its own event
    clock (the [G, C] semantics within one cell are exactly
    :func:`simulate_batch`'s).  Returns finish times ``[B, G, C]``; with
    ``with_report=True`` returns ``(finish, SweepReport)``.  Cells are
    bucketed by live-cloudlet count, chunked with donated buffers, and
    sharded across devices — bit-identical to the monolithic dispatch.
    """
    import numpy as np
    from ..kernels.ops import resolve_use_pallas
    from .sweep import execute_sweep
    use_pallas = resolve_use_pallas(use_pallas)
    length = np.asarray(length, np.float64)
    pes = np.asarray(pes, np.float64)
    submit = np.asarray(submit, np.float64)
    guest_mips = np.asarray(guest_mips, np.float64)
    guest_pes = np.asarray(guest_pes, np.float64)
    # Per-cell slot canonicalization (space-shared FIFO is arrival-ordered).
    order = np.argsort(submit + np.arange(submit.shape[-1]) * 1e-12, axis=-1,
                       kind="stable")
    inv = np.argsort(order, axis=-1, kind="stable")
    params = (np.take_along_axis(length, order, -1),
              np.take_along_axis(pes, order, -1),
              np.take_along_axis(submit, order, -1),
              guest_mips, guest_pes)
    # Loop length ≈ events ≈ live cloudlets (+ their submissions).
    pred = np.count_nonzero(length > 0, axis=(1, 2)) + 1
    with jax.experimental.enable_x64():
        out, report = execute_sweep(
            _batched_cells(mode, bool(use_pallas)), params,
            chunk_size=chunk_size, devices=devices, donate=donate,
            predicted_cost=pred)
    finish = np.take_along_axis(out["finish"], inv, -1)
    return (finish, report) if with_report else finish


# -- backend substrate handlers ------------------------------------------------

@scenario("cloudlet_batch", backends=("vec",))
def _cloudlet_batch_vec(backend: SimBackend, *, length, pes, submit,
                        guest_mips, guest_pes, mode: str = "time",
                        use_pallas: bool | str = False, **sweep_kw):
    """Finish times via the compiled SoA path: ``[G, C]`` inputs run the
    single-problem global-clock simulator; ``[B, G, C]`` inputs run a batch
    of independent cells through the sweep layer (``chunk_size`` /
    ``devices`` / ``with_report`` accepted)."""
    import numpy as np
    if np.asarray(length).ndim == 3:
        return simulate_cells(length, pes, submit, guest_mips, guest_pes,
                              mode, use_pallas=use_pallas, **sweep_kw)
    return simulate_batch(length, pes, submit, guest_mips, guest_pes, mode,
                          use_pallas=use_pallas)


@scenario("cloudlet_batch", backends=("legacy", "oo"))
def _cloudlet_batch_oo(backend: SimBackend, *, length, pes, submit,
                       guest_mips, guest_pes, mode: str = "time",
                       use_pallas: bool = False):
    """Finish times [G, C] via the OO engine (reference semantics; inf for
    empty/unfinished slots) — same contract as the vec handler.  ``[B, G,
    C]`` inputs loop the engine over the independent cells.  Sweep controls
    (``with_report``/``chunk_size``/``devices``) are deliberately *not*
    accepted: this handler has no sweep path, and ``backend.run_sweep``'s
    contract is a ``TypeError`` rather than a silently-dropped report."""
    import numpy as np
    if np.asarray(length).ndim == 3:
        return np.stack([
            _cloudlet_batch_oo(backend, length=length[b], pes=pes[b],
                               submit=submit[b], guest_mips=guest_mips[b],
                               guest_pes=guest_pes[b], mode=mode)
            for b in range(np.asarray(length).shape[0])])
    from .datacenter import Broker, Datacenter
    from .entities import Cloudlet, Host, Vm
    from .scheduler import (CloudletSchedulerSpaceShared,
                            CloudletSchedulerTimeShared)
    length = np.asarray(length, np.float64)
    pes = np.asarray(pes, np.float64)
    submit = np.asarray(submit, np.float64)
    G, C = length.shape
    sim = backend.make_simulation()
    hosts = [Host(num_pes=int(guest_pes[g]), mips=float(guest_mips[g]),
                  ram=1e9, bw=1e9) for g in range(G)]
    dc = Datacenter(sim, hosts)
    broker = Broker(sim, dc)
    guests = []
    for g in range(G):
        sch = (CloudletSchedulerTimeShared() if mode == "time"
               else CloudletSchedulerSpaceShared())
        vm = Vm(sch, num_pes=int(guest_pes[g]), mips=float(guest_mips[g]),
                ram=1024, bw=1e9)
        broker.add_guest(vm, on_host=hosts[g])
        guests.append(vm)
    cls = {}
    for t, g, c in sorted((submit[g, c], g, c) for g in range(G)
                          for c in range(C) if length[g, c] > 0):
        cl = Cloudlet(length=float(length[g, c]), pes=int(pes[g, c]))
        cls[(g, c)] = cl
        broker.submit(cl, guests[g], at=float(t))
    sim.run()
    out = np.full((G, C), np.inf)
    for (g, c), cl in cls.items():
        out[g, c] = cl.finish_time if cl.finish_time >= 0 else np.inf
    return out
