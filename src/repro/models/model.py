"""Unified model builder: ``Model(cfg)`` covers all 10 assigned families.

A model is a stack of (mixer, ffn) blocks over token/frame/patch embeddings:

  family   mixer per layer          ffn per layer
  dense    attn                     swiglu mlp
  moe      attn                     MoE (every/rem per config)
  ssm      rwkv6 time-mix           rwkv6 channel-mix
  hybrid   jamba pattern m/a        mlp | MoE on odd layers
  audio    attn (bidirectional)     mlp           (encoder-only, frame stub)
  vlm      attn                     mlp           (patch-embed prefix stub)

The layer stack is grouped into homogeneous *super-blocks* of
``len(block_pattern)`` layers (1 for non-hybrid archs) and scanned with
``lax.scan`` over stacked params (`cfg.scan_layers`), keeping HLO size and
compile time depth-independent; `cfg.remat` wraps each super-block in
``jax.checkpoint``. The dry-run's cost extrapolation compiles depth-1/2
*unrolled* variants (see EXPERIMENTS.md §Method).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act import constrain
from . import layers as L
from . import mamba as M
from . import moe as X
from . import rwkv6 as R

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# RWKV channel-mix (the ssm family's ffn)
# --------------------------------------------------------------------------

def cm_init(rng, cfg: ArchConfig) -> Params:
    import math
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "mix_k": jnp.zeros((D,), jnp.float32) + 0.5,
        "mix_r": jnp.zeros((D,), jnp.float32) + 0.5,
        "wk": jax.random.normal(k1, (D, F), jnp.float32) / math.sqrt(D),
        "wv": jax.random.normal(k2, (F, D), jnp.float32) / math.sqrt(F),
        "wr": jax.random.normal(k3, (D, D), jnp.float32) / math.sqrt(D),
    }


def cm_specs(cfg: ArchConfig) -> Params:
    return {"mix_k": ("embed",), "mix_r": ("embed",),
            "wk": ("embed", "mlp"), "wv": ("mlp", "embed"),
            "wr": ("embed", "embed_out")}


def cm_apply(p: Params, cfg: ArchConfig, x, x_last=None):
    dt = x.dtype
    B, S, D = x.shape
    prev = jnp.concatenate(
        [jnp.zeros((B, 1, D), dt) if x_last is None else x_last.astype(dt),
         x[:, :-1]], axis=1)
    xk = x + (prev - x) * p["mix_k"].astype(dt)
    xr = x + (prev - x) * p["mix_r"].astype(dt)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(dt))))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)))
    return r * kv, x[:, -1:]


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    moe_impl: str = "onehot"        # "onehot" | "sort"  (§Perf lever)
    seq_impl: str = "chunked"       # "chunked" (exact assoc-scan) | "scan"
                                    # | "chunked_cost" (dry-run FLOP model;
                                    #   mamba only — rwkv maps it to chunked)

    # -- block pattern -------------------------------------------------------
    def pattern(self) -> List[Tuple[str, str]]:
        """[(mixer, ffn)] for one super-block."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return [("rwkv", "cm")]
        mixers = list(cfg.block_pattern) or ["a"]
        out = []
        for i, mx in enumerate(mixers):
            if cfg.moe is not None and i % cfg.moe.every == cfg.moe.rem:
                ffn = "moe"
            else:
                ffn = "mlp"
            out.append(("attn" if mx == "a" else "mamba", ffn))
        return out

    @property
    def n_groups(self) -> int:
        pat = len(self.pattern())
        assert self.cfg.n_layers % pat == 0
        return self.cfg.n_layers // pat

    # -- init ------------------------------------------------------------------
    def _init_one(self, rng, mixer: str, ffn: str) -> Params:
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        mix = {"attn": L.attention_init, "mamba": M.mamba_init,
               "rwkv": R.rwkv_init}[mixer](k1, cfg)
        f = {"mlp": L.mlp_init, "moe": X.moe_init, "cm": cm_init}[ffn](k2, cfg)
        return {"norm1": L.rms_norm_init(cfg.d_model), "mixer": mix,
                "norm2": L.rms_norm_init(cfg.d_model), "ffn": f}

    def init(self, rng) -> Params:
        cfg = self.cfg
        pat = self.pattern()
        rngs = jax.random.split(rng, self.n_groups * len(pat) + 2)
        blocks = []
        for pos, (mx, ffn) in enumerate(pat):
            per_group = [self._init_one(rngs[g * len(pat) + pos], mx, ffn)
                         for g in range(self.n_groups)]
            if cfg.scan_layers:
                blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))
            else:
                blocks.append(per_group)
        return {"embed": L.embed_init(rngs[-2], cfg),
                "blocks": blocks,
                "final_norm": L.rms_norm_init(cfg.d_model)}

    def specs(self) -> Params:
        """Logical-axis tree mirroring init() (stacked ⇒ leading 'layers')."""
        cfg = self.cfg
        out_blocks = []
        for mx, ffn in self.pattern():
            mix = {"attn": L.attention_specs, "mamba": M.mamba_specs,
                   "rwkv": R.rwkv_specs}[mx](cfg)
            f = {"mlp": L.mlp_specs, "moe": X.moe_specs, "cm": cm_specs}[ffn](cfg)
            blk = {"norm1": {"scale": (None,)}, "mixer": mix,
                   "norm2": {"scale": (None,)}, "ffn": f}
            if cfg.scan_layers:
                blk = jax.tree.map(lambda sp: ("layers",) + tuple(sp), blk,
                                   is_leaf=lambda v: isinstance(v, tuple))
            else:
                blk = [blk] * self.n_groups
            out_blocks.append(blk)
        return {"embed": L.embed_specs(cfg), "blocks": out_blocks,
                "final_norm": {"scale": (None,)}}

    # -- one super-block ----------------------------------------------------------
    def _block(self, p: Params, x, *, pos_idx: int, positions, cache,
               cache_index):
        cfg = self.cfg
        mx, ffn = self.pattern()[pos_idx]
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
        h = L.rms_norm(p["norm1"], x, cfg.norm_eps)
        new_cache = None
        if mx == "attn":
            h, new_cache = L.attention_apply(
                p["mixer"], cfg, h, positions=positions, causal=cfg.causal,
                cache=None if cache is None else (cache["k"], cache["v"]),
                cache_index=cache_index)
            if new_cache is not None:
                new_cache = {"k": new_cache[0], "v": new_cache[1]}
        elif mx == "mamba":
            st = None if cache is None else (cache["conv"], cache["h"])
            h, st = M.mamba_apply(p["mixer"], cfg, h, state=st,
                                  impl=self.seq_impl)
            if cache is not None:
                new_cache = {"conv": st[0], "h": st[1]}
        elif mx == "rwkv":
            st = None if cache is None else (cache["x_tm"], cache["wkv"])
            h, st = R.rwkv_apply(p["mixer"], cfg, h, state=st, impl=self.seq_impl)
            if cache is not None:
                new_cache = {"x_tm": st[0], "wkv": st[1]}
        x = x + h
        f = L.rms_norm(p["norm2"], x, cfg.norm_eps)
        if ffn == "mlp":
            f = L.mlp_apply(p["ffn"], f)
        elif ffn == "moe":
            f = X.moe_apply(p["ffn"], cfg, f, impl=self.moe_impl)
        elif ffn == "cm":
            x_last = None if cache is None else cache["x_cm"]
            f, x_last = cm_apply(p["ffn"], cfg, f, x_last)
            if new_cache is not None:
                new_cache["x_cm"] = x_last
        return x + f, new_cache

    def _super_block(self, group_params: List[Params], x, *, positions,
                     group_cache, cache_index):
        new_caches = []
        for pos_idx, p in enumerate(group_params):
            c = None if group_cache is None else group_cache[pos_idx]
            x, nc = self._block(p, x, pos_idx=pos_idx, positions=positions,
                                cache=c, cache_index=cache_index)
            new_caches.append(nc)
        return x, (new_caches if group_cache is not None else None)

    # -- full forward -----------------------------------------------------------
    def _stack(self, params: Params, x, *, positions, cache, cache_index):
        cfg = self.cfg
        pat = self.pattern()
        remat_policy = {"none": None, "full": None,
                        "dots": jax.checkpoint_policies.checkpoint_dots}[cfg.remat]

        def sb(gp, x_, gc):
            return self._super_block(gp, x_, positions=positions,
                                     group_cache=gc, cache_index=cache_index)

        if cfg.remat != "none":
            sb = jax.checkpoint(sb, policy=remat_policy,
                                static_argnums=())
        if cfg.scan_layers:
            def body(carry, xs):
                x_, = carry
                gp = [xs[f"b{i}"] for i in range(len(pat))]
                gc = None if cache is None else [xs[f"c{i}"] for i in range(len(pat))]
                x_, nc = sb(gp, x_, gc)
                out = {} if nc is None else {f"c{i}": nc[i] for i in range(len(pat))}
                return (x_,), out
            xs = {f"b{i}": params["blocks"][i] for i in range(len(pat))}
            if cache is not None:
                xs.update({f"c{i}": cache[i] for i in range(len(pat))})
            (x,), new_cache = jax.lax.scan(body, (x,), xs)
            if cache is not None:
                new_cache = [new_cache[f"c{i}"] for i in range(len(pat))]
            else:
                new_cache = None
        else:
            new_cache = [] if cache is not None else None
            for g in range(self.n_groups):
                gp = [params["blocks"][i][g] for i in range(len(pat))]
                gc = None if cache is None else [cache[i][g] for i in range(len(pat))]
                x, nc = sb(gp, x, gc)
                if cache is not None:
                    new_cache.append(nc)
            if cache is not None:
                # regroup [group][pos] -> [pos][group]
                new_cache = [[new_cache[g][i] for g in range(self.n_groups)]
                             for i in range(len(pat))]
        return x, new_cache

    def apply(self, params: Params, batch: Dict[str, jax.Array], *,
              cache=None, cache_index=None) -> Tuple[jax.Array, Any]:
        """Returns (logits [B,S,V], new_cache)."""
        cfg = self.cfg
        if cfg.frontend == "audio":
            x = batch["frames"].astype(L.dtype_of(cfg))     # stub frontend
            positions = jnp.arange(x.shape[1])
        elif cfg.frontend == "vision" and "patches" in batch:
            # patch-embed prefix (stub frontend); works with or without a
            # cache (vision prefill writes the prefix through the cache)
            tok = L.embed_apply(params["embed"], cfg, batch["tokens"])
            patches = batch["patches"].astype(tok.dtype) + \
                params["embed"]["patch_pos"].astype(tok.dtype)
            x = jnp.concatenate([patches, tok], axis=1)
            if cache_index is None:
                positions = jnp.arange(x.shape[1])
            else:
                idx = jnp.asarray(cache_index)
                positions = (idx[:, None] if idx.ndim == 1 else idx) \
                    + jnp.arange(x.shape[1])
        else:
            x = L.embed_apply(params["embed"], cfg, batch["tokens"])
            if cache_index is None:
                positions = jnp.arange(x.shape[1])
            else:
                idx = jnp.asarray(cache_index)
                positions = (idx[:, None] if idx.ndim == 1 else idx) \
                    + jnp.arange(x.shape[1])
        x = constrain(x, ("act_batch", "act_seq", "act_embed"))
        x, new_cache = self._stack(params, x, positions=positions,
                                   cache=cache, cache_index=cache_index)
        x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = L.head_apply(params["embed"], cfg, x)
        return logits, new_cache

    # -- losses / steps -----------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        logits, _ = self.apply(params, batch)
        labels = batch["labels"]
        if cfg.frontend == "vision":
            logits = logits[:, -labels.shape[1]:]           # text positions only
        # Streamed cross-entropy: never materializes log_softmax [B,S,V] in
        # fp32 — logsumexp + label gather fuse into per-element passes
        # (the fp32 [B,S,V] copy dominated dry-run temp memory otherwise).
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        shifted = logits - m
        lse = jnp.log(jnp.sum(jnp.exp(shifted.astype(jnp.float32)), axis=-1)) \
            + m[..., 0].astype(jnp.float32)
        picked = jnp.take_along_axis(logits, labels[..., None],
                                     axis=-1)[..., 0].astype(jnp.float32)
        nll = lse - picked
        mask = batch.get("mask", jnp.ones_like(nll))
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # -- decode cache -----------------------------------------------------------
    def init_cache(self, batch_size: int, max_seq: int) -> Any:
        cfg = self.cfg
        assert cfg.causal, "encoder-only archs have no decode step"
        pat = self.pattern()
        G = self.n_groups
        caches = []
        for mx, ffn in pat:
            if mx == "attn":
                shp = (batch_size, max_seq, cfg.n_kv_heads, cfg.hd)
                c = {"k": jnp.zeros(shp, jnp.bfloat16),
                     "v": jnp.zeros(shp, jnp.bfloat16)}
            elif mx == "mamba":
                c = {"conv": jnp.zeros((batch_size, cfg.d_conv - 1,
                                        2 * cfg.d_model), jnp.bfloat16),
                     "h": jnp.zeros((batch_size, 2 * cfg.d_model, cfg.d_state),
                                    jnp.float32)}
            else:  # rwkv
                c = {"x_tm": jnp.zeros((batch_size, 1, cfg.d_model), jnp.bfloat16),
                     "wkv": jnp.zeros((batch_size, cfg.n_heads, cfg.hd, cfg.hd),
                                      jnp.float32)}
            if ffn == "cm":
                c["x_cm"] = jnp.zeros((batch_size, 1, cfg.d_model), jnp.bfloat16)
            if cfg.scan_layers:
                c = jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), c)
            else:
                c = [c] * G
            caches.append(c)
        return caches

    @property
    def cache_batch_axis(self) -> int:
        """Batch axis position in cache leaves (1 when layer-stacked)."""
        return 1 if self.cfg.scan_layers else 0

    def serve_step(self, params: Params, cache, tokens: jax.Array,
                   cache_index) -> Tuple[jax.Array, Any]:
        """One decode step: tokens [B,1] → (logits [B,1,V], new_cache).
        ``cache_index``: scalar, or [B] per-slot positions."""
        logits, new_cache = self.apply(params, {"tokens": tokens},
                                       cache=cache, cache_index=cache_index)
        return logits, new_cache


def build(cfg: ArchConfig, **kw) -> Model:
    return Model(cfg, **kw)
