"""Core transformer building blocks (pure-function JAX, param pytrees).

Conventions:
  * params are nested dicts of arrays; a parallel tree of *logical axis*
    tuples (see ``specs`` functions) drives sharding via
    ``repro.distributed.sharding``.
  * logical axes: "embed" (d_model), "vocab", "q_heads", "kv_heads",
    "head_dim", "mlp", "experts", "layers", "state", "conv".
  * attention over long KV is computed in statically-unrolled KV chunks with
    an online softmax (flash-attention schedule in pure XLA) — bounds the
    S_q×S_kv score buffer AND keeps FLOPs visible to ``cost_analysis`` (an
    inner ``lax.scan`` would hide them; see EXPERIMENTS.md §Method).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act import constrain

Params = Dict[str, Any]

# KV chunk size for blocked attention (also the Pallas kernel's macro-tile).
ATTN_CHUNK = 4096


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# norms / rope
# --------------------------------------------------------------------------

def rms_norm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"]).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs          # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # [..., S, 1, half]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA / MQA / MHA; optional qk-norm; causal or bidirectional)
# --------------------------------------------------------------------------

def padded_heads(cfg: ArchConfig) -> int:
    return max(cfg.n_heads, cfg.pad_q_heads or 0)


def attention_init(rng, cfg: ArchConfig) -> Params:
    D, H, K, hd = cfg.d_model, padded_heads(cfg), cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(D)
    wq = jax.random.normal(k1, (D, H, hd), jnp.float32) * s
    wo = jax.random.normal(k4, (H, hd, D), jnp.float32) * (1.0 / math.sqrt(H * hd))
    if H > cfg.n_heads:
        # padding heads are structurally zero: identical function, dense
        # sharding (see ArchConfig.pad_q_heads). Padding is PER KV-GROUP
        # (layout h = k*G_pad + g) so real heads keep their kv assignment.
        assert H % K == 0 and cfg.n_heads % K == 0
        g_pad, g_real = H // K, cfg.n_heads // K
        mask = ((jnp.arange(H) % g_pad) < g_real).astype(jnp.float32)
        wq = wq * mask[None, :, None]
        wo = wo * mask[:, None, None]
    p = {
        "wq": wq,
        "wk": jax.random.normal(k2, (D, K, hd), jnp.float32) * s,
        "wv": jax.random.normal(k3, (D, K, hd), jnp.float32) * s,
        "wo": wo,
    }
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(hd)
        p["k_norm"] = rms_norm_init(hd)
    return p


def attention_specs(cfg: ArchConfig) -> Params:
    p = {
        "wq": ("embed", "q_heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("q_heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": (None,)}
        p["k_norm"] = {"scale": (None,)}
    return p


def _online_attn(q, k, v, *, causal: bool, q_offset, chunk: int):
    """Blocked attention with online softmax over KV chunks.

    q: [B,Sq,H,hd]  k,v: [B,Skv,K,hd]  (H = K·G)
    q_offset: absolute position of q[0] — scalar, or [B] for per-row decode
    positions (continuous batching). Statically unrolled KV chunks.
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd) * (1.0 / math.sqrt(hd))
    acc = jnp.zeros((B, Sq, K, G, hd), jnp.float32)
    m = jnp.full((B, Sq, K, G), -jnp.inf, jnp.float32)
    l = jnp.zeros((B, Sq, K, G), jnp.float32)
    n_chunks = max(1, (Skv + chunk - 1) // chunk)
    for ci in range(n_chunks):
        lo = ci * chunk
        hi = min(lo + chunk, Skv)
        kc = k[:, lo:hi].astype(jnp.float32)
        vc = v[:, lo:hi].astype(jnp.float32)
        s = jnp.einsum("bqkgh,bskh->bqkgs", qg.astype(jnp.float32), kc)
        if causal:
            off = jnp.asarray(q_offset)
            off = off[:, None] if off.ndim == 1 else off[None, None]
            qpos = off + jnp.arange(Sq)[None, :]                   # [B|1, Sq]
            kpos = lo + jnp.arange(hi - lo)
            mask = qpos[:, :, None] >= kpos[None, None, :]         # [B|1,Sq,Sc]
            s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bqkgs,bskh->bqkgh", p, vc)
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, H, hd)


def attention_apply(p: Params, cfg: ArchConfig, x: jax.Array, *,
                    positions: jax.Array, causal: bool,
                    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                    cache_index: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """x: [B,S,D] → [B,S,D]. With ``cache`` (k,v of [B,S_max,K,hd]) performs
    incremental decode: writes new kv at ``cache_index`` and attends to the
    full cache prefix."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q = constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k = constrain(k, ("act_batch", "act_seq", "act_kv_heads", None))
    v = constrain(v, ("act_batch", "act_seq", "act_kv_heads", None))
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        ck, cv = cache
        idx = jnp.asarray(cache_index)
        if idx.ndim == 0:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, idx, 0, 0))
        else:
            # per-row positions (continuous batching): vmapped row updates
            upd = jax.vmap(lambda c, new, i: jax.lax.dynamic_update_slice(
                c, new, (i, 0, 0)))
            ck = upd(ck, k.astype(ck.dtype), idx)
            cv = upd(cv, v.astype(cv.dtype), idx)
        new_cache = (ck, cv)
        k, v = ck, cv
        q_offset = idx            # scalar or [B]; masks stale slots away
    else:
        q_offset = 0
    # decode (Sq==1): scores are tiny, use large chunks to limit HLO size
    chunk = ATTN_CHUNK if q.shape[1] > 1 else 65536
    out = _online_attn(q, k, v, causal=causal or cache is not None,
                       q_offset=q_offset, chunk=chunk)
    out = constrain(out, ("act_batch", "act_seq", "act_heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out.astype(dt), p["wo"].astype(dt))
    return constrain(y, ("act_batch", "act_seq", "act_embed")), new_cache


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def mlp_init(rng, cfg: ArchConfig) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": jax.random.normal(k1, (D, F), jnp.float32) / math.sqrt(D),
        "w_up": jax.random.normal(k2, (D, F), jnp.float32) / math.sqrt(D),
        "w_down": jax.random.normal(k3, (F, D), jnp.float32) / math.sqrt(F),
    }


def mlp_specs(cfg: ArchConfig) -> Params:
    return {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
            "w_down": ("mlp", "embed")}


def mlp_apply(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    g = constrain(g, ("act_batch", "act_seq", "act_mlp"))
    u = constrain(u, ("act_batch", "act_seq", "act_mlp"))
    y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"].astype(dt))
    return constrain(y, ("act_batch", "act_seq", "act_embed"))


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------

def embed_init(rng, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(rng)
    p = {"tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02}
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab),
                                      jnp.float32) / math.sqrt(cfg.d_model)
    if cfg.frontend == "vision":
        p["patch_pos"] = jnp.zeros((cfg.n_patches, cfg.d_model), jnp.float32)
    return p


def embed_specs(cfg: ArchConfig) -> Params:
    # tok table: vocab-sharded only — data-sharding D as well makes the
    # token gather unpartitionable (observed "involuntary full remat" SPMD
    # warning on the multi-pod mesh); 'embed_tok' maps to None.
    p = {"tok": ("vocab", "embed_tok")}
    if not cfg.tie_embeddings:
        p["head"] = ("embed", "vocab")
    if cfg.frontend == "vision":
        p["patch_pos"] = (None, "embed")
    return p


def embed_apply(p: Params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    return p["tok"].astype(dtype_of(cfg))[tokens]


def head_apply(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return constrain(logits, ("act_batch", "act_seq", "act_vocab"))
