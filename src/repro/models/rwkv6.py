"""RWKV-6 "Finch" time-mix block — data-dependent decay linear attention.

Recurrence per head (head size N): S_t = diag(w_t)·S_{t-1} + k_tᵀv_t,
y_t = r_t·(S_{t-1} + diag(u)·k_tᵀv_t), with w_t = exp(-exp(ω(x_t))) the
*data-dependent* per-channel decay (the Finch contribution) and token-shift
ddlerp mixing (LoRA-modulated interpolation with x_{t-1}).

Two sequence implementations (cfg-independent, chosen per call):
  * "chunked" — FLA-style intra-chunk factorized matmuls
      Ã[t,j] = (r_t∘e^{cl_{t-1}})·(k_j∘e^{-cl_j}) with strict-lower mask,
    inter-chunk state carried exactly; statically unrolled over chunks so
    every FLOP is visible to ``cost_analysis`` (the dry-run path, and the
    Pallas kernel's schedule).
  * "scan" — exact sequential ``lax.scan`` oracle (tests, tiny real runs).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act import constrain

Params = Dict[str, Any]

LORA_R = 32           # decay/mix LoRA rank (official 6.x uses 32 for 7B)
RWKV_CHUNK = 64


def rwkv_init(rng, cfg: ArchConfig) -> Params:
    D = cfg.d_model
    H, N = cfg.n_heads, cfg.hd
    ks = jax.random.split(rng, 12)
    s = 1.0 / math.sqrt(D)
    return {
        # ddlerp token-shift: base mixes + one shared LoRA trunk (5 targets)
        "mix_base": jnp.zeros((6, D), jnp.float32) + 0.5,   # x,w,k,v,r,g
        "lora_A": jax.random.normal(ks[0], (D, 5 * LORA_R), jnp.float32) * s,
        "lora_B": jax.random.normal(ks[1], (5, LORA_R, D), jnp.float32) * 0.01,
        # projections
        "wr": jax.random.normal(ks[2], (D, H, N), jnp.float32) * s,
        "wk": jax.random.normal(ks[3], (D, H, N), jnp.float32) * s,
        "wv": jax.random.normal(ks[4], (D, H, N), jnp.float32) * s,
        "wg": jax.random.normal(ks[5], (D, H, N), jnp.float32) * s,
        "wo": jax.random.normal(ks[6], (H, N, D), jnp.float32) / math.sqrt(D),
        # decay: ω(x) = w0 + tanh(x̃ @ dA) @ dB  (per channel)
        "w0": jnp.zeros((H, N), jnp.float32) - 4.0,
        "decay_A": jax.random.normal(ks[7], (D, 64), jnp.float32) * s,
        "decay_B": jax.random.normal(ks[8], (64, H * N), jnp.float32) * 0.01,
        "u": jax.random.normal(ks[9], (H, N), jnp.float32) * 0.1,  # bonus
        "ln_x": {"scale": jnp.ones((H * N,), jnp.float32)},        # group norm
    }


def rwkv_specs(cfg: ArchConfig) -> Params:
    return {
        "mix_base": (None, "embed"),
        "lora_A": ("embed", None),
        "lora_B": (None, None, "embed"),
        "wr": ("embed", "q_heads", "head_dim"),
        "wk": ("embed", "q_heads", "head_dim"),
        "wv": ("embed", "q_heads", "head_dim"),
        "wg": ("embed", "q_heads", "head_dim"),
        "wo": ("q_heads", "head_dim", "embed"),
        "w0": ("q_heads", "head_dim"),
        "decay_A": ("embed", None),
        "decay_B": (None, "q_heads"),
        "u": ("q_heads", "head_dim"),
        "ln_x": {"scale": (None,)},
    }


def _ddlerp(p: Params, x: jax.Array, x_prev: jax.Array):
    """Finch token-shift: returns the 5 mixed streams (w,k,v,r,g)."""
    dt = x.dtype
    xx = x_prev - x
    xxx = x + xx * p["mix_base"][0].astype(dt)
    trunk = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, p["lora_A"].astype(dt)))
    trunk = trunk.reshape(*trunk.shape[:-1], 5, LORA_R)
    delta = jnp.einsum("bsir,ird->bsid", trunk, p["lora_B"].astype(dt))
    mixes = p["mix_base"][1:].astype(dt)                      # [5, D]
    return [x + xx * (mixes[i] + delta[..., i, :]) for i in range(5)]


def _proj_heads(x, w):
    return jnp.einsum("bsd,dhn->bshn", x, w.astype(x.dtype))


def _wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """r,k,v: [B,S,H,N]; logw: [B,S,H,N] (log decay ≤ 0); state: [B,H,N,N].

    Returns (y [B,S,H,N], state_out). Statically unrolled chunks; fp32 core.
    """
    B, S, H, N = r.shape
    r, k, v = (t.astype(jnp.float32) for t in (r, k, v))
    logw = logw.astype(jnp.float32)
    y = jnp.zeros((B, S, H, N), jnp.float32)
    n_chunks = max(1, (S + chunk - 1) // chunk)
    for ci in range(n_chunks):
        lo, hi = ci * chunk, min((ci + 1) * chunk, S)
        L = hi - lo
        rc, kc, vc = r[:, lo:hi], k[:, lo:hi], v[:, lo:hi]
        lw = logw[:, lo:hi]
        cl = jnp.cumsum(lw, axis=1)                            # [B,L,H,N]
        cl_prev = cl - lw                                      # cl_{t-1}
        r_t = rc * jnp.exp(cl_prev)                            # r̃
        k_t = kc * jnp.exp(-jnp.maximum(cl, -30.0))            # k̃ (clamped)
        A = jnp.einsum("bthn,bjhn->bhtj", r_t, k_t)            # [B,H,L,L]
        mask = jnp.tril(jnp.ones((L, L), bool), k=-1)          # strict lower
        A = jnp.where(mask[None, None], A, 0.0)
        bonus = jnp.einsum("bthn,bthn->bth", rc * u[None, None], kc)
        y_intra = jnp.einsum("bhtj,bjhn->bthn", A, vc) + bonus[..., None] * vc
        y_inter = jnp.einsum("bthn,bhnm->bthm", r_t, state)
        y = y.at[:, lo:hi].set(y_intra + y_inter)
        # carry state: S' = diag(e^{cl_L}) S + Σ_j (k_j ∘ e^{cl_L - cl_j}) v_jᵀ
        decay_all = jnp.exp(cl[:, -1])                         # [B,H,N] (k-dim)
        k_s = kc * jnp.exp(cl[:, -1:, :, :] - cl)
        state = state * decay_all[..., None] \
            + jnp.einsum("bjhn,bjhm->bhnm", k_s, vc)
    return y, state


def _wkv_scan(r, k, v, logw, u, state):
    """Exact sequential oracle."""
    B, S, H, N = r.shape
    r, k, v = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(logw.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp                                   # [B,H,N]…
        out = jnp.einsum("bhn,bhnm->bhm", rt, s) + \
            jnp.einsum("bhn,bhn,bhm->bhm", rt, u[None] * kt, vt)
        s = s * wt[..., None] + jnp.einsum("bhn,bhm->bhnm", kt, vt)
        return s, out

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def rwkv_apply(p: Params, cfg: ArchConfig, x: jax.Array, *,
               state: Tuple[jax.Array, jax.Array] = None,
               impl: str = "chunked"
               ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """x: [B,S,D] → [B,S,D].  state = (x_last [B,1,D], S [B,H,N,N]) for
    incremental decode; None ⇒ zeros (fresh sequence)."""
    B, S, D = x.shape
    H, N = cfg.n_heads, cfg.hd
    dt = x.dtype
    if state is None:
        x_last = jnp.zeros((B, 1, D), dt)
        wkv_state = jnp.zeros((B, H, N, N), jnp.float32)
    else:
        x_last, wkv_state = state
    x_prev = jnp.concatenate([x_last, x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    r = constrain(_proj_heads(xr, p["wr"]), ("act_batch", "act_seq", "act_heads", None))
    k = constrain(_proj_heads(xk, p["wk"]), ("act_batch", "act_seq", "act_heads", None))
    v = constrain(_proj_heads(xv, p["wv"]), ("act_batch", "act_seq", "act_heads", None))
    g = jax.nn.silu(_proj_heads(xg, p["wg"]))
    dec = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["decay_A"].astype(dt)))
    omega = p["w0"].reshape(-1).astype(jnp.float32) + \
        jnp.einsum("bsr,rz->bsz", dec.astype(jnp.float32), p["decay_B"])
    logw = -jnp.exp(omega).reshape(B, S, H, N)                  # log decay ≤ 0
    u = p["u"].astype(jnp.float32)
    if impl in ("chunked", "chunked_cost") and S > 1:
        # chunk scales with S: bounded unrolled-block count (compile time)
        chunk = max(RWKV_CHUNK, S // 64)
        y, wkv_state = _wkv_chunked(r, k, v, logw, u, wkv_state, chunk)
    else:
        y, wkv_state = _wkv_scan(r, k, v, logw, u, wkv_state)
    # per-head group norm, gate, out-proj
    y = y.reshape(B, S, H * N)
    mean = jnp.mean(y.reshape(B, S, H, N), axis=-1, keepdims=True)
    var = jnp.var(y.reshape(B, S, H, N), axis=-1, keepdims=True)
    y = ((y.reshape(B, S, H, N) - mean) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, H * N)
    y = (y * p["ln_x"]["scale"]).astype(dt).reshape(B, S, H, N)
    y = y * g
    out = jnp.einsum("bshn,hnd->bsd", y, p["wo"].astype(dt))
    out = constrain(out, ("act_batch", "act_seq", "act_embed"))
    return out, (x[:, -1:], wkv_state)
