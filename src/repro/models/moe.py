"""Mixture-of-Experts FFN — GShard-style capacity-based dispatch (baseline)
and a sort-based dispatch (beyond-paper hillclimb alternative).

Baseline ("onehot"): top-k routing, per-group capacity C = ⌈k·cf·S_g/E⌉,
dispatch/combine via one-hot einsums. SPMD-friendly (resharding between the
token-sharded and expert-sharded einsums lowers to all-to-all), but pays the
classic GShard dispatch-einsum tax (~2·E·C·D extra FLOPs per group) and
materializes a [S_g, E, C] mask per group — both visible in the roofline and
attacked in §Perf.

Alternative ("sort"): argsort tokens by expert, gather into [E, C, D]
buffers, grouped einsum, scatter back. Same math (capacity drops included);
no one-hot einsum FLOPs.

Routing math (both paths): softmax over E, take top-k, renormalize the k
gates to sum 1. Tokens over capacity are *dropped* (contribute zero — their
residual stream passes through), the standard capacity-factor semantics.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act import constrain

Params = Dict[str, Any]

import os
MOE_GROUP = int(os.environ.get("REPRO_MOE_GROUP", "1024"))   # tokens/group
# (GShard-style; env-overridable — the dispatch-tax §Perf lever: one-hot
# mask bytes and dispatch-einsum FLOPs both scale ∝ group size)


def moe_init(rng, cfg: ArchConfig) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "router": jax.random.normal(k1, (D, E), jnp.float32) / math.sqrt(D),
        "w_gate": jax.random.normal(k2, (E, D, F), jnp.float32) / math.sqrt(D),
        "w_up": jax.random.normal(k3, (E, D, F), jnp.float32) / math.sqrt(D),
        "w_down": jax.random.normal(k4, (E, F, D), jnp.float32) / math.sqrt(F),
    }


def moe_specs(cfg: ArchConfig) -> Params:
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }


def _routing(p: Params, xg: jax.Array, cfg: ArchConfig):
    """xg: [G, S, D] → (weights [G,S,k], experts [G,S,k])."""
    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(xg.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, topi = jax.lax.top_k(probs, cfg.moe.top_k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)
    return topw, topi


def _capacity(cfg: ArchConfig, s_g: int) -> int:
    E, k, cf = cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.capacity_factor
    return max(4, int(math.ceil(k * cf * s_g / E)))


def moe_apply_onehot(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: [B,S,D]. Baseline GShard one-hot dispatch."""
    B, S, D = x.shape
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    s_g = min(MOE_GROUP, B * S)
    assert (B * S) % s_g == 0, "token count must divide the MoE group size"
    G = (B * S) // s_g
    C = _capacity(cfg, s_g)
    xg = constrain(x.reshape(G, s_g, D), ("act_group", None, None))
    topw, topi = _routing(p, xg, cfg)                             # [G,s,k]
    # position of each (token, choice) within its expert queue
    onehot_e = jax.nn.one_hot(topi, E, dtype=jnp.float32)         # [G,s,k,E]
    # priority: choice-major then token order (GShard's flattened cumsum)
    flat = onehot_e.transpose(0, 2, 1, 3).reshape(G, k * s_g, E)  # [G,k*s,E]
    pos_flat = jnp.cumsum(flat, axis=1) - flat                    # rank in queue
    pos = pos_flat.reshape(G, k, s_g, E).transpose(0, 2, 1, 3)    # [G,s,k,E]
    pos = jnp.sum(pos * onehot_e, axis=-1)                        # [G,s,k]
    keep = pos < C
    gate = topw * keep                                            # dropped → 0
    onehot_c = jax.nn.one_hot(pos, C, dtype=jnp.float32)          # [G,s,k,C]
    # dispatch mask [G,s,E,C] (the tax), combine with gates
    dispatch = jnp.einsum("gske,gskc->gsec", onehot_e, onehot_c * keep[..., None])
    combine = jnp.einsum("gske,gskc,gsk->gsec", onehot_e, onehot_c, gate)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)   # [G,E,C,D]
    xe = constrain(xe, ("act_group", "act_experts", None, None))
    h = _expert_ffn(p, xe)                                            # [G,E,C,D]
    h = constrain(h, ("act_group", "act_experts", None, None))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), h)
    return out.reshape(B, S, D)


def moe_apply_sort(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: [B,S,D]. Sort-based dispatch (no one-hot einsum FLOPs)."""
    B, S, D = x.shape
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    s_g = min(MOE_GROUP, B * S)
    assert (B * S) % s_g == 0, "token count must divide the MoE group size"
    G = (B * S) // s_g
    C = _capacity(cfg, s_g)
    xg = x.reshape(G, s_g, D)
    topw, topi = _routing(p, xg, cfg)                             # [G,s,k]

    def per_group(xg1, topi1, topw1):
        # flatten (token, choice) pairs; choice-major order matches onehot path
        e_flat = topi1.T.reshape(-1)                              # [k*s]
        w_flat = topw1.T.reshape(-1)
        t_flat = jnp.tile(jnp.arange(s_g), (k,))                  # token ids
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        pos_in_e = jnp.arange(k * s_g) - jnp.searchsorted(
            e_sorted, e_sorted, side="left")                      # rank in expert
        keep = pos_in_e < C
        slot = jnp.where(keep, e_sorted * C + pos_in_e, E * C)    # overflow bin
        buf = jnp.zeros((E * C + 1, D), xg1.dtype)
        buf = buf.at[slot].set(xg1[t_flat[order]])
        h = _expert_ffn(p, buf[: E * C].reshape(1, E, C, D))[0]   # [E,C,D]
        h_flat = jnp.concatenate([h.reshape(E * C, D),
                                  jnp.zeros((1, D), h.dtype)])
        y_sorted = h_flat[slot] * w_flat[order][:, None]
        y = jnp.zeros((s_g, D), xg1.dtype).at[t_flat[order]].add(
            y_sorted.astype(xg1.dtype))
        return y

    out = jax.vmap(per_group)(xg, topi, topw)
    return out.reshape(B, S, D)


def _expert_ffn(p: Params, xe: jax.Array) -> jax.Array:
    """xe: [G,E,C,D] → [G,E,C,D] (SwiGLU per expert)."""
    dt = xe.dtype
    g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
    return jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * u, p["w_down"].astype(dt))


def moe_apply(p: Params, cfg: ArchConfig, x: jax.Array, *,
              impl: str = "onehot") -> jax.Array:
    if impl == "onehot":
        return moe_apply_onehot(p, cfg, x)
    if impl == "sort":
        return moe_apply_sort(p, cfg, x)
    raise ValueError(impl)
