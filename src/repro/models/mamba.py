"""Mamba (S6) block — selective state-space mixer used by Jamba's "m" layers.

Per channel c (of d_inner) and state n (of d_state):
  h_t = exp(Δ_t·A)∘h_{t-1} + Δ_t·B_t·x_t ;   y_t = C_t·h_t + D∘x_t
with input-dependent Δ (softplus), B, C (the selectivity), causal depthwise
conv front, and SiLU gate z.

Implementations:
  * "chunked"      — exact: within-chunk associative_scan (stable, parallel,
                     FLOP-visible); default for real execution.
  * "chunked_cost" — dry-run cost model: cumsum/exp form, HLO-cheap and
                     FLOP-faithful to a TPU kernel's sequential chunk scan,
                     numerically clamped (never used for real runs).
  * "scan"         — exact sequential lax.scan oracle (tests, decode).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.act import constrain

Params = Dict[str, Any]

MAMBA_CHUNK = 64
DT_RANK_DIV = 16      # dt_rank = d_model / 16 (mamba default ceil(D/16))


def mamba_init(rng, cfg: ArchConfig) -> Params:
    D, N = cfg.d_model, cfg.d_state
    d_in = 2 * D
    dt_rank = max(1, D // DT_RANK_DIV)
    ks = jax.random.split(rng, 8)
    s = 1.0 / math.sqrt(D)
    return {
        "w_in": jax.random.normal(ks[0], (D, 2 * d_in), jnp.float32) * s,
        "conv": jax.random.normal(ks[1], (cfg.d_conv, d_in), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "w_dt": jax.random.normal(ks[2], (d_in, dt_rank), jnp.float32) * s,
        "w_dt_up": jax.random.normal(ks[3], (dt_rank, d_in), jnp.float32) * 0.1,
        "dt_bias": jnp.zeros((d_in,), jnp.float32) + math.log(math.e - 1),
        "w_B": jax.random.normal(ks[4], (d_in, N), jnp.float32) * s,
        "w_C": jax.random.normal(ks[5], (d_in, N), jnp.float32) * s,
        "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                  (d_in, 1))),
        "D_skip": jnp.ones((d_in,), jnp.float32),
        "w_out": jax.random.normal(ks[6], (d_in, D), jnp.float32) / math.sqrt(d_in),
    }


def mamba_specs(cfg: ArchConfig) -> Params:
    return {
        "w_in": ("embed", "mlp"), "conv": (None, "mlp"), "conv_b": ("mlp",),
        "w_dt": ("mlp", None), "w_dt_up": (None, "mlp"), "dt_bias": ("mlp",),
        "w_B": ("mlp", None), "w_C": ("mlp", None),
        "A_log": ("mlp", "state"), "D_skip": ("mlp",),
        "w_out": ("mlp", "embed"),
    }


def _ssm_chunked(u, loga, C, chunk: int, h0):
    """u: [B,S,di,N] inputs (Δ·B·x);  loga: [B,S,di,N] log decay (≤0);
    C: [B,S,N];  h0: [B,di,N].  Returns (y [B,S,di], h_out).

    Within a chunk the linear recurrence h_t = a_t·h_{t-1} + u_t is solved
    with ``associative_scan`` over (a, b) pairs — exact and stable (only
    products of a ≤ 1 appear; no e^{-cl} division, which silently zeroed
    *fresh* contributions under strong decay — a bug this replaced). The
    scan unrolls to log-depth elementwise HLO, so its FLOPs stay visible
    to ``cost_analysis`` (unlike a ``lax.scan`` loop).
    """
    B, S, di, N = u.shape
    y = jnp.zeros((B, S, di), jnp.float32)
    h = h0
    n_chunks = max(1, (S + chunk - 1) // chunk)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    for ci in range(n_chunks):
        lo, hi = ci * chunk, min((ci + 1) * chunk, S)
        uc, lac, Cc = u[:, lo:hi], loga[:, lo:hi], C[:, lo:hi]
        a = jnp.exp(lac)                                     # [B,L,di,N] ≤ 1
        A, Bk = jax.lax.associative_scan(combine, (a, uc), axis=1)
        hs = A * h[:, None] + Bk                             # [B,L,di,N]
        y = y.at[:, lo:hi].set(jnp.einsum("bldn,bln->bld", hs, Cc))
        h = hs[:, -1]
    return y, h


def _ssm_chunked_cost(u, loga, C, chunk: int, h0):
    """Dry-run cost variant: cumsum/exp form (clamped). Numerically unsafe
    under strong decay (fresh contributions vanish past the clamp) but
    HLO-cheap to compile and FLOP-faithful to a TPU kernel's sequential
    in-register chunk scan — which is what the cost analysis should price.
    Never used for real execution (build paths select "chunked"/"scan")."""
    B, S, di, N = u.shape
    y = jnp.zeros((B, S, di), jnp.float32)
    h = h0
    n_chunks = max(1, (S + chunk - 1) // chunk)
    for ci in range(n_chunks):
        lo, hi = ci * chunk, min((ci + 1) * chunk, S)
        uc, lac, Cc = u[:, lo:hi], loga[:, lo:hi], C[:, lo:hi]
        cl = jnp.cumsum(lac, axis=1)
        u_sc = uc * jnp.exp(jnp.minimum(-cl, 30.0))
        hs = jnp.exp(cl) * (h[:, None] + jnp.cumsum(u_sc, axis=1))
        y = y.at[:, lo:hi].set(jnp.einsum("bldn,bln->bld", hs, Cc))
        h = hs[:, -1]
    return y, h


def _ssm_scan(u, loga, C, h0):
    B, S, di, N = u.shape

    def step(h, inp):
        ut, lat, Ct = inp
        h = jnp.exp(lat) * h + ut
        return h, jnp.einsum("bdn,bn->bd", h, Ct)

    xs = (u.transpose(1, 0, 2, 3), loga.transpose(1, 0, 2, 3),
          C.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h


def mamba_apply(p: Params, cfg: ArchConfig, x: jax.Array, *,
                state: Tuple[jax.Array, jax.Array] = None,
                impl: str = "chunked"
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """x: [B,S,D] → [B,S,D]. state = (conv_tail [B,d_conv-1,di], h [B,di,N])."""
    B, S, D = x.shape
    N, dc = cfg.d_state, cfg.d_conv
    d_in = 2 * D
    dt = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt))
    xz = constrain(xz, ("act_batch", "act_seq", "act_mlp"))
    xi, z = jnp.split(xz, 2, axis=-1)                         # [B,S,di] each
    if state is None:
        conv_tail = jnp.zeros((B, dc - 1, d_in), dt)
        h0 = jnp.zeros((B, d_in, N), jnp.float32)
    else:
        conv_tail, h0 = state
    # causal depthwise conv (statically unrolled over d_conv taps)
    xpad = jnp.concatenate([conv_tail.astype(dt), xi], axis=1)  # [B,S+dc-1,di]
    conv = p["conv"].astype(dt)
    xc = sum(xpad[:, i: i + S] * conv[i] for i in range(dc)) + p["conv_b"].astype(dt)
    xc = jax.nn.silu(xc)
    new_conv_tail = xpad[:, S:]                                # last dc-1 inputs
    # selective SSM parameters
    dt_lo = jnp.einsum("bsd,dr->bsr", xc, p["w_dt"].astype(dt))
    delta = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_lo,
                                       p["w_dt_up"].astype(dt)).astype(jnp.float32)
                            + p["dt_bias"])                    # [B,S,di] fp32
    Bt = jnp.einsum("bsd,dn->bsn", xc, p["w_B"].astype(dt)).astype(jnp.float32)
    Ct = jnp.einsum("bsd,dn->bsn", xc, p["w_C"].astype(dt)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                   # [di,N] (<0)
    loga = delta[..., None] * A[None, None]                    # [B,S,di,N]
    u = (delta * xc.astype(jnp.float32))[..., None] * Bt[:, :, None, :]
    if impl == "chunked" and S > 1:
        chunk = max(MAMBA_CHUNK, S // 32)   # bounded unrolled-block count
        y, h = _ssm_chunked(u, loga, Ct, chunk, h0)
    elif impl == "chunked_cost" and S > 1:
        chunk = max(MAMBA_CHUNK, S // 32)
        y, h = _ssm_chunked_cost(u, loga, Ct, chunk, h0)
    else:
        y, h = _ssm_scan(u, loga, Ct, h0)
    y = y + p["D_skip"] * xc.astype(jnp.float32)
    y = (y.astype(dt)) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt))
    out = constrain(out, ("act_batch", "act_seq", "act_embed"))
    return out, (new_conv_tail, h)
