# One <arch>.py per assigned architecture (+ tiny reduced variants + the
# paper's own simulation scenario configs live in repro.core.case_study).
from .base import (ARCH_IDS, SHAPES, ArchConfig, MoEConfig, ShapeConfig,
                   applicable_shapes, load_arch, load_tiny)
