"""Architecture configuration schema + the shape grid.

One ``ArchConfig`` per assigned architecture lives in
``repro/configs/<id>.py`` (exact public-literature configs) alongside a
``tiny()`` reduced variant of the same family for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    every: int = 1                 # MoE FFN on layers where i % every == rem
    rem: int = 0


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free (rwkv uses its own)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    causal: bool = True            # False => encoder-only (no decode shapes)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    # hybrid (jamba): mixer per layer position within a repeating block
    block_pattern: Tuple[str, ...] = ()     # e.g. ("m","m","m","m","a","m","m","m")
    # ssm / mamba / rwkv dims
    d_state: int = 16
    d_conv: int = 4
    # modality frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"
    n_patches: int = 256           # vision stub prefix length
    # runtime knobs (hillclimb surface)
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "full"            # none | full | dots (checkpoint policy)
    use_pallas: bool = False       # TPU-only fast path; CPU uses XLA ref
    zero3: bool = True             # shard params/opt over the data axis (FSDP)
    pad_q_heads: int = 0           # pad attention Q heads to this count with
                                   # structurally-zero heads (function-
                                   # preserving) so heads divide the model
                                   # axis — §Perf lever for 36/40-head archs

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def param_count(self) -> int:
        """Exact parameter count (mirrors models/*.py init structure)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, K, hd = self.n_heads, self.n_kv_heads, self.hd
        N = self.d_state
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.frontend == "vision":
            emb += self.n_patches * D                        # patch_pos
        total = emb + D                                      # final_norm
        lora_r = 32
        for i in range(L):
            mixer = self.block_pattern[i % len(self.block_pattern)] \
                if self.block_pattern else ("r" if self.family == "ssm" else "a")
            if mixer == "a":
                total += D * hd * (H + 2 * K) + H * hd * D
                if self.qk_norm:
                    total += 2 * hd
            elif mixer == "m":                               # mamba block
                d_in = 2 * D
                dt_rank = max(1, D // 16)
                total += (D * 2 * d_in                       # w_in
                          + self.d_conv * d_in + d_in        # conv + bias
                          + d_in * dt_rank + dt_rank * d_in + d_in  # dt
                          + 2 * d_in * N                     # w_B, w_C
                          + d_in * N + d_in                  # A_log, D_skip
                          + d_in * D)                        # w_out
            elif mixer == "r":                               # rwkv6 time-mix
                HN = H * hd
                total += (6 * D                              # mix_base
                          + D * 5 * lora_r + 5 * lora_r * D  # lora A/B
                          + 4 * D * HN + HN * D              # r,k,v,g,o
                          + HN                               # w0
                          + D * 64 + 64 * HN                 # decay lora
                          + HN + HN)                         # u + ln_x
            if self.family == "ssm":                          # channel mix
                total += 2 * D + D * F + F * D + D * D
            elif self.moe is not None and i % self.moe.every == self.moe.rem:
                total += D * self.moe.n_experts + self.moe.n_experts * 3 * D * F
            else:
                total += 3 * D * F                           # swiglu
            total += 2 * D                                   # norms
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k of experts)."""
        if self.moe is None:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        inactive = 0
        for i in range(L):
            if i % self.moe.every == self.moe.rem:
                inactive += (self.moe.n_experts - self.moe.top_k) * 3 * D * F
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "starcoder2_7b", "qwen3_8b", "llama3_405b", "granite_20b", "rwkv6_7b",
    "hubert_xlarge", "moonshot_v1_16b_a3b", "llama4_scout_17b_a16e",
    "jamba_v0_1_52b", "internvl2_2b",
]


def applicable_shapes(cfg: ArchConfig) -> list:
    """The brief's skip rules (documented in DESIGN.md §4)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.causal:
        out.append("decode_32k")
        subquadratic = cfg.family in ("ssm", "hybrid")
        if subquadratic:
            out.append("long_500k")
    return out


def load_arch(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def load_tiny(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.tiny()
