"""Qwen3-8B [hf:Qwen/Qwen3-8B] — dense, GQA kv=8, qk_norm."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_8b", family="dense", n_layers=36, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=12288, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, scan_layers=False, remat="none")
