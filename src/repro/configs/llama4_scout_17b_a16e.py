"""Llama-4-Scout-17B-16E [hf:meta-llama; unverified] — MoE 16e top-1.
Modeled with full attention (released chunked-attention iRoPE variant out of
scope) and without the shared expert — both noted in DESIGN.md."""
import dataclasses
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4_scout_17b_a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
    rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=1, capacity_factor=1.25),
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512, moe=MoEConfig(n_experts=4, top_k=1),
        scan_layers=False, remat="none")
