"""StarCoder2-7B [arXiv:2402.19173; hf] — dense, GQA kv=4, RoPE."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv_heads=4, d_ff=18432, vocab=49152, head_dim=128,
    rope_theta=1e5,
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab=512, scan_layers=False, remat="none")
