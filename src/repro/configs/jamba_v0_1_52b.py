"""Jamba-v0.1 52B [arXiv:2403.19887; hf] — hybrid Mamba+attention 1:7
(attn at offset 4 of each 8-layer block), MoE 16e top-2 on odd layers."""
import dataclasses
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba_v0_1_52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=65536, head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=2, every=2, rem=1),
    block_pattern=("m", "m", "m", "m", "a", "m", "m", "m"),
    d_state=16, d_conv=4,
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512, moe=MoEConfig(n_experts=4, top_k=2, every=2, rem=1),
        d_state=4, scan_layers=False, remat="none")
