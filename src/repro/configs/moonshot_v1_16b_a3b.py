"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B] — MoE 64e top-6,
d_ff=1408 per expert (no shared expert modeled — see DESIGN.md)."""
import dataclasses
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonshot_v1_16b_a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840, head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, capacity_factor=1.25),
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=512, moe=MoEConfig(n_experts=8, top_k=2),
        scan_layers=False, remat="none")
