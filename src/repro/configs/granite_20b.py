"""Granite-20B-Code [arXiv:2405.04324; hf] — dense, MQA (kv=1), llama-arch."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite_20b", family="dense", n_layers=52, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab=49152, head_dim=128,
    rope_theta=1e4,
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, scan_layers=False, remat="none")
