"""InternVL2-2B [arXiv:2404.16821; hf] — InternLM2-1.8B backbone + InternViT
frontend (stubbed: input_specs feeds precomputed patch embeddings)."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab=92553, head_dim=128,
    rope_theta=1e6, frontend="vision", n_patches=256,
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, n_patches=16, scan_layers=False, remat="none")
