"""Llama-3.1-405B [arXiv:2407.21783; unverified] — dense, GQA kv=8, 128k vocab."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama3_405b", family="dense", n_layers=126, d_model=16384,
    n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256, head_dim=128,
    rope_theta=5e5,
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=512, scan_layers=False, remat="none")
