"""RWKV-6 "Finch" 7B [arXiv:2404.05892; hf] — attention-free, data-dependent
decay; 64 wkv heads of size 64."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_7b", family="ssm", n_layers=32, d_model=4096,
    n_heads=64, n_kv_heads=64, d_ff=14336, vocab=65536, head_dim=64,
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, scan_layers=False, remat="none")
