"""HuBERT X-Large [arXiv:2106.07447; unverified] — encoder-only audio
backbone (conv frontend stubbed: input_specs feeds frame embeddings);
vocab=504 masked-prediction codebook."""
import dataclasses
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert_xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504, head_dim=80,
    causal=False, frontend="audio",
)

def tiny() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=64, scan_layers=False, remat="none")
