"""Activation sharding constraints (MaxText-style).

Without explicit constraints, GSPMD's propagation can pick pathological
layouts (e.g. batch-replicated fp32 activation all-reduces for ZeRO-sharded
weights — observed on the first dry-run of this repo). Model code therefore
pins the layout of key activations via ``constrain(x, logical_axes)``.

The mesh+rules context is set around tracing (``use_act_sharding``);
``constrain`` is a no-op when no context is active, so model code runs
unchanged on a single device.

Activation logical axes (defaults; §Perf overrides per experiment):
  act_batch → ("pod","data")   act_heads/act_kv_heads/act_mlp/act_experts/
  act_seq   → None                act_vocab → "model"
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from .sharding import resolve_spec

ACT_RULES_BASE: Dict[str, Any] = {
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "act_vocab": "model",
    "act_group": ("pod", "data"),     # MoE dispatch groups
    None: None,
}

_tls = threading.local()


def _ctx() -> Optional[Tuple[Mesh, Dict[str, Any]]]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def use_act_sharding(mesh: Mesh, overrides: Optional[Dict[str, Any]] = None):
    rules = dict(ACT_RULES_BASE)
    if overrides:
        rules.update({k: v for k, v in overrides.items()
                      if k.startswith("act_")})
    prev = _ctx()
    _tls.ctx = (mesh, rules)
    try:
        yield
    finally:
        _tls.ctx = prev


def constrain(x: jax.Array, logical: Tuple) -> jax.Array:
    ctx = _ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(x.shape, tuple(logical), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
