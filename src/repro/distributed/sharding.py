"""Logical-axis → mesh sharding resolution.

Model code annotates every parameter with *logical* axis names
(see ``Model.specs()``); this module maps them onto mesh axes with
divisibility-aware fallback (a dim that doesn't divide its mesh axis is
replicated rather than erroring — e.g. kv_heads=8 on a 16-way model axis)
and first-come-first-served conflict resolution (one mesh axis at most once
per tensor).

Default rules (MaxText-style 2D sharding):
  tensor-parallel axes  : vocab / q_heads / kv_heads / mlp / experts → "model"
  ZeRO-3 (FSDP) axis    : embed → "data" (cfg.zero3; optimizer state and
                          params shard over data; XLA inserts the
                          all-gather / reduce-scatter pairs)
  batch                 : ("pod", "data")
Rules are a plain dict — the §Perf hillclimb overrides them per experiment.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_RULES_BASE: Dict[str, Any] = {
    "vocab": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "embed": "data",          # ZeRO-3/FSDP; dropped when cfg.zero3=False
    "embed_tok": None,        # token table: vocab-sharded only (gather-safe)
    "embed_out": "model",
    "layers": None,
    "state": None,
    "conv": None,
    None: None,
}


def rules_for(cfg, overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    rules = dict(LOGICAL_RULES_BASE)
    if not getattr(cfg, "zero3", True):
        rules["embed"] = None
    if overrides:
        rules.update(overrides)
    return rules


def _present(mesh: Mesh, axis):
    """Drop mesh axes absent from this mesh (e.g. 'pod' on a single pod)."""
    if axis is None:
        return None
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def resolve_spec(shape: Tuple[int, ...], logical: Tuple, mesh: Mesh,
                 rules: Dict[str, Any]) -> P:
    """Logical axes + concrete shape → PartitionSpec (divisibility-aware)."""
    used = set()
    out = []
    for dim, name in zip(shape, logical):
        axis = _present(mesh, rules.get(name))
        ok = axis is not None
        if ok:
            axes = axis if isinstance(axis, (tuple, list)) else (axis,)
            ok = all(a not in used for a in axes) \
                and dim % _axis_size(mesh, axis) == 0 and dim > 0
        if ok:
            out.append(axis)
            used.update(axes)
        else:
            out.append(None)
    return P(*out)


def shard_tree(tree_shapes, tree_logical, mesh: Mesh, rules) -> Any:
    """ShapeDtypeStruct tree + logical tree → NamedSharding tree."""
    def one(sds, logical):
        spec = resolve_spec(sds.shape, tuple(logical), mesh, rules)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, tree_shapes, tree_logical,
                        is_leaf=lambda v: isinstance(v, jax.ShapeDtypeStruct))


def batch_axes(mesh: Mesh):
    """Mesh axes used for the data-parallel batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Shard dim0 (batch) over pod×data when divisible, else replicate."""
    axes = batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and shape[0] % size == 0 and shape[0] > 0:
        return P(axes, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def cache_spec(shape: Tuple[int, ...], kind: str, mesh: Mesh,
               stacked: bool) -> P:
    """Decode-cache sharding. Layout (maybe-stacked leading 'layers' dim):
       attn k/v: [B, S, K, hd] — batch→pod×data; K→model if divisible,
       else S→model (context-parallel cache; the §Perf baseline/lever).
       mamba/rwkv states: batch→pod×data; channel dim→model if divisible."""
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    axes = batch_axes(mesh)
    dp = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    b = axes if (axes and body[0] % dp == 0 and body[0] > 0) else None
    model = mesh.shape.get("model", 1)
    if kind == "attn_kv" and len(body) == 4:
        _, S, K, _ = body
        if K % model == 0 and model > 1:
            return P(*lead, b, None, "model", None)
        if S % model == 0 and model > 1:
            return P(*lead, b, "model", None, None)
        return P(*lead, b, None, None, None)
    # state-ish tensors: try to shard the largest non-batch dim over model
    rest = [None] * (len(body) - 1)
    if len(body) >= 2:
        sizes = list(body[1:])
        order = sorted(range(len(sizes)), key=lambda i: -sizes[i])
        for i in order:
            if sizes[i] % model == 0 and model > 1:
                rest[i] = "model"
                break
    return P(*lead, b, *rest)
