"""Batched serving engine: slot-based continuous batching with a KV cache.

A fixed pool of ``batch_size`` slots decodes in lockstep (one jitted step
per token across all slots). Each slot tracks its OWN cache position
(vectorized ``cache_index``), so a freed slot restarts a new request at
position 0: its fresh keys progressively overwrite the previous occupant's
entries and the per-row causal mask makes any stale suffix unreachable.
Recurrent state (RWKV/Mamba) is zeroed on admission instead (cache
surgery on the slot's batch row).

Prompt prefill is streamed through the same step (simple + correct; a
production variant batches prefill separately).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import Model, build


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 4
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_token: int = -1            # -1 ⇒ run to max_new_tokens
    seed: int = 0


@dataclasses.dataclass
class _Slot:
    request_id: int = -1
    pos: int = 0                   # this slot's next cache position
    remaining: int = 0
    out: List[int] = dataclasses.field(default_factory=list)
    prompt: List[int] = dataclasses.field(default_factory=list)
    last_token: int = 0
    active: bool = False


class ServeEngine:
    def __init__(self, arch: ArchConfig, params, cfg: ServeConfig):
        assert arch.causal, "encoder-only archs are not served autoregressively"
        self.arch = arch
        self.cfg = cfg
        self.model: Model = build(arch, seq_impl="scan")
        self.params = params
        self.cache = self.model.init_cache(cfg.batch_size, cfg.max_seq)
        self.slots = [_Slot() for _ in range(cfg.batch_size)]

        def step(params, cache, tokens, index_vec):
            logits, cache = self.model.apply(params, {"tokens": tokens},
                                             cache=cache,
                                             cache_index=index_vec)
            return logits[:, -1], cache

        self._step = jax.jit(step)

    def _zero_slot_state(self, i: int) -> None:
        """Zero recurrent (non-KV) state for slot ``i`` on admission."""
        axis = self.model.cache_batch_axis

        def zero(path, leaf):
            key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if key in ("k", "v"):
                return leaf                     # positions handle staleness
            idx = (slice(None),) * axis + (i,)
            return leaf.at[idx].set(0)

        self.cache = jax.tree_util.tree_map_with_path(zero, self.cache)

    def generate(self, prompts: Sequence[Sequence[int]]) -> List[List[int]]:
        cfg = self.cfg
        queue = list(enumerate(prompts))
        results: Dict[int, List[int]] = {}
        B = cfg.batch_size
        tokens = np.zeros((B, 1), np.int32)
        index = np.zeros((B,), np.int32)

        def admit(i: int):
            s = self.slots[i]
            if not queue:
                s.active = False
                return
            rid, prompt = queue.pop(0)
            s.request_id = rid
            s.prompt = list(prompt)
            s.out = []
            s.remaining = cfg.max_new_tokens
            s.pos = 0
            s.active = True
            self._zero_slot_state(i)

        for i in range(B):
            admit(i)

        while any(s.active for s in self.slots):
            for i, s in enumerate(self.slots):
                if not s.active:
                    tokens[i, 0] = 0
                    index[i] = min(s.pos, cfg.max_seq - 1)
                elif s.pos < len(s.prompt):
                    tokens[i, 0] = s.prompt[s.pos]
                    index[i] = s.pos
                else:
                    tokens[i, 0] = s.last_token
                    index[i] = s.pos
            logits, self.cache = self._step(self.params, self.cache,
                                            jnp.asarray(tokens),
                                            jnp.asarray(index))
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for i, s in enumerate(self.slots):
                if not s.active:
                    continue
                s.pos += 1
                if s.pos < len(s.prompt):
                    continue                     # still prefilling
                tok = int(nxt[i])
                s.out.append(tok)
                s.last_token = tok
                s.remaining -= 1
                if (s.remaining <= 0 or tok == cfg.eos_token
                        or s.pos >= cfg.max_seq - 1):
                    results[s.request_id] = s.out
                    admit(i)                     # continuous batching
        return [results.get(i, []) for i in range(len(prompts))]
