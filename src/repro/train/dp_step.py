"""Explicit-collective data-parallel step via shard_map, with optional int8
error-feedback gradient compression on the reduction axis.

pjit hides gradient reductions inside XLA; cross-pod (DCN) reductions are
the one place where *changing the bytes on the wire* pays, so this variant
makes the all-reduce explicit (``shard_map`` + ``psum``) and quantizes
per-tensor to int8 with an error-feedback residual (optim/compression.py):
4× fewer DCN bytes for <1e-3 relative gradient error per step, unbiased in
the long run. Used for the 'pod' axis of the production mesh; intra-pod
(ICI) reductions stay exact.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.optim import clip_by_global_norm
from repro.optim.compression import ErrorFeedbackState, compressed_psum, ef_init


def make_dp_train_step(model, opt, mesh: Mesh, *, axis: str = "data",
                       lr: float = 1e-3, clip: float = 1.0,
                       compress: bool = True):
    """Returns (step_fn, ef_init_fn). Params replicated over ``axis``;
    batch row-sharded; gradients reduced with (compressed) psum."""

    def local_step(params, opt_state, batch, ef):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if compress:
            grads, ef = compressed_psum(grads, ef, axis)
        else:
            grads = jax.lax.pmean(grads, axis)
        loss = jax.lax.pmean(loss, axis)
        grads, _ = clip_by_global_norm(grads, clip)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, loss, ef

    pspec = P()                               # replicated params/opt/ef
    bspec = jax.tree.map(lambda _: P(axis), {"tokens": 0, "labels": 0})
    step = shard_map(local_step, mesh=mesh,
                     in_specs=(pspec, pspec, bspec, pspec),
                     out_specs=(pspec, pspec, pspec, pspec),
                     check_rep=False)
    return jax.jit(step), ef_init
