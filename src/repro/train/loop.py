"""Fault-tolerant training loop.

Demonstrates (and tests assert) the fleet-scale behaviours the cluster
simulator models at the 1000-node scale:
  * periodic async checkpointing (atomic; see checkpoint/manager.py);
  * failure → restart-from-latest (``SimulatedFailure`` injection), with
    the data pipeline's counter-mode skip-ahead replaying the exact stream;
  * determinism across restarts: a run with failures reaches bit-identical
    params to an uninterrupted run (asserted in tests);
  * optional cross-pod int8 error-feedback gradient compression via
    ``shard_map`` (optim/compression.py) when a 'pod' mesh axis exists.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import DataConfig, TokenPipeline
from repro.models.model import build
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer


class SimulatedFailure(RuntimeError):
    """Injected node failure (training process dies, restarts from ckpt)."""


@dataclasses.dataclass
class TrainConfig:
    steps: int = 50
    ckpt_every: int = 10
    lr: float = 1e-3
    warmup: int = 10
    clip: float = 1.0
    optimizer: str = "adamw"
    seed: int = 0
    log_every: int = 10
    async_ckpt: bool = True


@dataclasses.dataclass
class TrainResult:
    losses: List[float]
    final_step: int
    restarts: int
    params: Dict
    steps_per_sec: float


def train(arch: ArchConfig, tcfg: TrainConfig, workdir: str, *,
          failure_at: Optional[Set[int]] = None,
          on_step: Optional[Callable[[int, float], None]] = None
          ) -> TrainResult:
    failure_at = set(failure_at or ())
    model = build(arch, seq_impl="scan")
    opt = make_optimizer(tcfg.optimizer)
    sched = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps)
    ckpt = CheckpointManager(workdir)
    pipe = TokenPipeline(DataConfig(vocab=arch.vocab, seq_len=64,
                                    global_batch=8, seed=tcfg.seed))

    @jax.jit
    def step_fn(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip)
        params, opt_state = opt.update(grads, opt_state, params, sched(step))
        return params, opt_state, loss, gnorm

    # -- init or resume --------------------------------------------------------
    params = model.init(jax.random.PRNGKey(tcfg.seed))
    opt_state = opt.init(params)
    state = {"params": params, "opt": opt_state}
    start = 0
    if ckpt.latest_step() is not None:
        state, start, _ = ckpt.restore(state)
        start += 1

    losses: List[float] = []
    restarts = 0
    step = start
    t0 = time.perf_counter()
    done_steps = 0
    while step < tcfg.steps:
        try:
            batch = pipe.batch(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            p, o, loss, gnorm = step_fn(state["params"], state["opt"],
                                        batch, step)
            if step in failure_at:
                failure_at.discard(step)        # fail once per step id
                raise SimulatedFailure(f"injected at step {step}")
            state = {"params": p, "opt": o}
            loss = float(loss)
            losses.append(loss)
            done_steps += 1
            if on_step:
                on_step(step, loss)
            if (step + 1) % tcfg.ckpt_every == 0:
                ckpt.save(state, step, blocking=not tcfg.async_ckpt)
            step += 1
        except SimulatedFailure:
            restarts += 1
            ckpt.wait()                          # in-flight async save lands
            last = ckpt.latest_step()
            if last is None:                     # crashed before first ckpt
                params = model.init(jax.random.PRNGKey(tcfg.seed))
                state = {"params": params, "opt": opt.init(params)}
                step = 0
            else:
                state, restored, _ = ckpt.restore(state)
                step = restored + 1              # pipeline skip-ahead is O(1)
    ckpt.wait()
    dt = time.perf_counter() - t0
    return TrainResult(losses=losses, final_step=step, restarts=restarts,
                       params=state["params"],
                       steps_per_sec=done_steps / max(dt, 1e-9))
