from .loop import SimulatedFailure, TrainConfig, TrainResult, train
