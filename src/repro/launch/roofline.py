"""Roofline-term extraction from compiled dry-run artifacts (TPU v5e target).

  compute_term    = HLO_FLOPs  / (chips × 197e12 FLOP/s)
  memory_term     = HLO_bytes  / (chips × 819e9 B/s)
  collective_term = coll_bytes / (chips × 50e9 B/s)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
from walking the post-SPMD HLO text (operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, async
``-start`` counted once). Shapes in the per-device program are *shards*, so
parsed sums are per-device; global = ×chips.

``cost_analysis`` counts ``while``-loop bodies ONCE (verified empirically),
so scanned-layer programs under-report. The dry-run therefore compiles
depth-1 and depth-2 *unrolled* variants of every cell and extrapolates
linearly in depth — exact for homogeneous stacks (the intercept carries
embedding/head/optimizer-fixed cost). See EXPERIMENTS.md §Method.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\(?[a-z][^=]*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(?:\[(\d+),(\d+)\]|\{\{([0-9, ]+)\})")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 1
    if m.group(2) is not None:
        return int(m.group(2))                 # iota form [n_groups, size]
    return len(m.group(3).split(","))          # explicit first group


def collective_bytes_per_device(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes by kind, parsed from scheduled HLO.

    Result types are parsed (scheduled HLO names operands without types);
    result == operand size for all-reduce / all-to-all / collective-permute;
    all-gather's result is the gathered (received) bytes; reduce-scatter's
    result is one shard, so it is scaled by the replica-group size to
    recover operand bytes. Async ``-start`` ops counted once (``-done``
    never matches: its operand is the start op, not a collective call)."""
    out: Dict[str, float] = {k: 0.0 for k in
                             ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all", "collective-permute")}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        kind = m.group(2)
        result_types = m.group(1)
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_types))
        if kind == "reduce-scatter":
            total *= _group_size(line)
        out[kind] += total
    out["total"] = sum(out.values())
    return out


def cost_of(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_of(compiled) -> Optional[Dict[str, float]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes", "host_temp_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["per_device_total"] = (out.get("argument_size_in_bytes", 0.0)
                                   - out.get("alias_size_in_bytes", 0.0)
                                   + out.get("output_size_in_bytes", 0.0)
                                   + out.get("temp_size_in_bytes", 0.0))
    return out or None


def extrapolate(cost1: Dict[str, float], cost2: Dict[str, float],
                n_groups: int) -> Dict[str, float]:
    """Linear-in-depth: cost(L) = a + b·L from L=1,2 super-block compiles."""
    out = {}
    for k in cost1:
        b = cost2[k] - cost1[k]
        a = cost1[k] - b
        out[k] = a + b * n_groups
    return out


def roofline_terms(flops_global: float, bytes_global: float,
                   coll_bytes_global: float, chips: int) -> Dict[str, float]:
    compute = flops_global / (chips * PEAK_FLOPS)
    memory = bytes_global / (chips * HBM_BW)
    collective = coll_bytes_global / (chips * LINK_BW)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["step_time_lower_bound_s"] = max(compute, memory) + collective
    return terms


def analytic_hbm_model(cfg, shape, mesh_shape: Dict[str, int],
                       optimizer: str = "adamw") -> Dict[str, float]:
    """Per-device HBM estimate (bytes) from first principles.

    Reported alongside ``memory_analysis`` because the CPU backend's
    ``temp_size_in_bytes`` over-approximates badly: CPU buffer assignment
    barely reuses transients (verified: two unrolled layers report ~2× one
    layer even under full remat), so it reflects *sum* of transients, not
    the TPU peak. Params/opt/grads/residual terms below are exact given the
    sharding rules; transients are a small multiple of one block's working
    set by construction (remat + scanned layers).
    """
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = max(model * data, 1)
    P = cfg.param_count()
    p_shard = model * (data if cfg.zero3 else 1)
    params_b = 2.0 * P / p_shard
    tokens_dev = shape.global_batch * shape.seq_len / max(data, 1)
    out = {"params": params_b}
    if shape.kind == "train":
        out["opt_state"] = (8.0 if optimizer == "adamw" else 1.0) * P / p_shard
        out["grads"] = 4.0 * P / p_shard                  # fp32 transient
        out["residuals"] = cfg.n_layers * tokens_dev * cfg.d_model * 2.0
        out["logits"] = tokens_dev * cfg.vocab / model * 4.0
        out["block_transient"] = 6.0 * tokens_dev * max(cfg.d_ff, 2 * cfg.d_model) \
            / model * 2.0
    elif shape.kind == "prefill":
        out["block_transient"] = 8.0 * tokens_dev * max(cfg.d_ff, 2 * cfg.d_model) \
            / model * 2.0
        out["logits"] = tokens_dev * cfg.vocab / model * 2.0
    else:  # decode: KV/state cache dominates
        n_attn = sum(1 for c in (cfg.block_pattern or "a" * 1)
                     if c == "a") * (cfg.n_layers // max(len(cfg.block_pattern), 1)) \
            if cfg.block_pattern else cfg.n_layers
        if cfg.family == "ssm":
            n_attn = 0
        kv_heads_shard = model if cfg.n_kv_heads % model == 0 else 1
        seq_shard = model if (kv_heads_shard == 1 and
                              shape.seq_len % model == 0) else 1
        cache = (2.0 * n_attn * shape.global_batch * shape.seq_len *
                 cfg.n_kv_heads * cfg.hd * 2.0 /
                 max(data if shape.global_batch % data == 0 else 1, 1) /
                 max(kv_heads_shard * seq_shard, 1))
        if cfg.family in ("ssm", "hybrid"):
            n_state = cfg.n_layers - n_attn
            cache += n_state * shape.global_batch * 2 * cfg.d_model * \
                max(cfg.d_state, cfg.hd if cfg.family == "ssm" else cfg.d_state) * 4.0
        out["cache"] = cache
    out["total"] = sum(out.values())
    out["total_gb"] = out["total"] / 1e9
    return out


def model_flops(cfg, shape) -> float:
    """MFU-convention useful FLOPs: 6·N_active·tokens (train) or 2·N_active·
    tokens (fwd-only); attention score/value FLOPs excluded (standard)."""
    n_active = cfg.active_param_count()
    # exclude embedding table lookups (gather, not matmul); the unembed
    # projection IS a matmul — keep it. tok embed rows = vocab·d once.
    n_active -= cfg.vocab * cfg.d_model
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n_active * tokens
