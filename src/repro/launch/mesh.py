"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init — the dry-run
sets XLA_FLAGS before any import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    """Arbitrary mesh (hillclimb experiments re-balance data↔model here)."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
