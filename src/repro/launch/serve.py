"""Serving launcher (continuous batching, slot-based KV cache).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_7b --tiny \
      --prompts "1,2,3;4,5" --max-new 16
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--prompts", default="1,2,3;4,5,6",
                    help="';'-separated prompts of ','-separated token ids")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt", default=None, help="restore params from dir")
    args = ap.parse_args()

    import jax
    from repro.configs.base import load_arch, load_tiny
    from repro.models.model import build
    from repro.serve import ServeConfig, ServeEngine

    cfg = load_tiny(args.arch) if args.tiny else load_arch(args.arch)
    model = build(cfg, seq_impl="scan")
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        from repro.checkpoint import CheckpointManager
        state = {"params": params}
        state, step, _ = CheckpointManager(args.ckpt).restore(state)
        params = state["params"]
        print(f"restored step {step} from {args.ckpt}")
    eng = ServeEngine(cfg, params, ServeConfig(batch_size=args.batch_size,
                                               max_seq=args.max_seq,
                                               max_new_tokens=args.max_new))
    prompts = [[int(t) for t in p.split(",") if t.strip()]
               for p in args.prompts.split(";")]
    for p, out in zip(prompts, eng.generate(prompts)):
        print(f"{p} -> {out}")


if __name__ == "__main__":
    main()
