"""Step builders: abstract inputs + sharded jitted train/prefill/serve steps.

Everything here works on ``ShapeDtypeStruct``s (no allocation) so the same
builders serve the 512-device dry-run and real (tiny) runs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import (batch_spec, cache_spec, resolve_spec,
                                        rules_for, shard_tree)
from repro.models.model import Model, build
from repro.optim import Optimizer, clip_by_global_norm, make_optimizer

SDS = jax.ShapeDtypeStruct


# --------------------------------------------------------------------------
# abstract trees
# --------------------------------------------------------------------------

def abstract_params(model: Model, param_dtype: Optional[str] = None):
    """ShapeDtypeStruct tree of model.init (optionally re-typed, e.g. bf16
    storage for the dry-run's memory realism)."""
    tree = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if param_dtype is not None:
        dt = jnp.dtype(param_dtype)
        tree = jax.tree.map(
            lambda s: SDS(s.shape, dt if s.dtype == jnp.float32 else s.dtype),
            tree)
    return tree


def param_shardings(model: Model, params_abs, mesh: Mesh, rules) -> Any:
    return shard_tree(params_abs, model.specs(), mesh, rules)


def opt_shardings(opt: Optimizer, params_abs, p_shardings, mesh: Mesh):
    """Optimizer-state shardings derived from param shardings."""
    state_abs = jax.eval_shape(opt.init, params_abs)
    repl = NamedSharding(mesh, P())

    flat_p, _ = jax.tree.flatten(params_abs)
    flat_s, _ = jax.tree.flatten(p_shardings)
    by_shape = {}
    for sds, sh in zip(flat_p, flat_s):
        by_shape.setdefault(sds.shape, sh)

    def one(s: SDS):
        if s.shape in by_shape:                       # m/v: same as param
            return by_shape[s.shape]
        # adafactor factored moments: match a param shape prefix/suffix
        for shape, sh in by_shape.items():
            if len(shape) >= 2 and s.shape == shape[:-1]:
                return NamedSharding(mesh, P(*sh.spec[: len(s.shape)]))
            if len(shape) >= 2 and s.shape == shape[:-2] + shape[-1:]:
                spec = list(sh.spec[: len(shape)])
                del spec[-2]
                return NamedSharding(mesh, P(*spec))
        return repl
    return jax.tree.map(one, state_abs)


# --------------------------------------------------------------------------
# inputs
# --------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh
                ) -> Dict[str, Any]:
    """Abstract model inputs (+ shardings attached) for one shape cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    def tok(shp, dtype=jnp.int32):
        return SDS(shp, dtype, sharding=NamedSharding(mesh, batch_spec(shp, mesh)))

    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            batch = {"frames": tok((B, S, cfg.d_model), dt),
                     "labels": tok((B, S))}
        elif cfg.frontend == "vision":
            s_text = S - cfg.n_patches
            batch = {"tokens": tok((B, s_text)),
                     "patches": tok((B, cfg.n_patches, cfg.d_model), dt),
                     "labels": tok((B, s_text))}
        else:
            batch = {"tokens": tok((B, S)), "labels": tok((B, S))}
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against an S-long cache
    return {"tokens": tok((B, 1)),
            "cache_index": SDS((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))}


def cache_abstract(model: Model, batch_size: int, max_seq: int, mesh: Mesh):
    """Abstract cache tree with shardings (see sharding.cache_spec)."""
    cache = jax.eval_shape(lambda: model.init_cache(batch_size, max_seq))
    stacked = model.cfg.scan_layers

    def annotate(path, s: SDS):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        kind = "attn_kv" if key in ("k", "v") else "state"
        spec = cache_spec(s.shape, kind, mesh, stacked)
        return SDS(s.shape, s.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(annotate, cache)


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def make_train_step(model: Model, opt: Optimizer, *, lr: float = 3e-4,
                    clip: float = 1.0):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        logits, _ = model.apply(params, batch)
        return logits
    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, batch):
        logits, cache = model.apply(params, {"tokens": batch["tokens"]},
                                    cache=cache,
                                    cache_index=batch["cache_index"])
        return logits, cache
    return serve_step


# --------------------------------------------------------------------------
# the full bundle for one (arch × shape × mesh) cell
# --------------------------------------------------------------------------

def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
               rule_overrides=None, optimizer: str = "adamw",
               moe_impl: str = "onehot", param_dtype: str = "bfloat16",
               seq_impl: str = "chunked_cost") -> Tuple[Any, Tuple]:
    """Returns (jitted_fn, abstract_args) ready for .lower(*args).

    ``seq_impl`` defaults to the dry-run cost variant (compile-cheap,
    FLOP-faithful to the TPU kernel target); real runs pass "chunked"/"scan".
    """
    from repro.distributed.act import use_act_sharding

    model = build(cfg, moe_impl=moe_impl, seq_impl=seq_impl)
    rules = rules_for(cfg, rule_overrides)
    params_abs = abstract_params(model, param_dtype)
    p_sh = param_shardings(model, params_abs, mesh, rules)
    params_abs = jax.tree.map(lambda s, sh: SDS(s.shape, s.dtype, sharding=sh),
                              params_abs, p_sh)
    batch = input_specs(cfg, shape, mesh)

    def under_act(fn):
        """Trace-time activation-sharding context (see distributed/act.py)."""
        @functools.wraps(fn)
        def wrapped(*a):
            with use_act_sharding(mesh, rule_overrides):
                return fn(*a)
        return wrapped

    if shape.kind == "train":
        opt = make_optimizer(optimizer)
        o_sh = opt_shardings(opt, params_abs, p_sh, mesh)
        opt_abs = jax.tree.map(lambda s, sh: SDS(s.shape, s.dtype, sharding=sh),
                               jax.eval_shape(opt.init, params_abs), o_sh)
        fn = jax.jit(under_act(make_train_step(model, opt)),
                     donate_argnums=(0, 1))
        return fn, (params_abs, opt_abs, batch)
    if shape.kind == "prefill":
        fn = jax.jit(under_act(make_prefill_step(model)))
        return fn, (params_abs, batch)
    # decode
    cache_abs = cache_abstract(model, shape.global_batch, shape.seq_len, mesh)
    fn = jax.jit(under_act(make_serve_step(model)), donate_argnums=(1,))
    return fn, (params_abs, cache_abs, batch)
