import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers,
compiles, and fits — and extract its roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both

Per cell this runs up to three compiles:
  full   — production config, scanned layers: the compile/memory/schedule
           proof (``memory_analysis`` + collective presence).
  cost×2 — depth-1 and depth-2 unrolled variants at identical widths/mesh:
           linear-in-depth extrapolation of FLOPs/bytes/collective bytes
           (cost_analysis counts while-bodies once; see roofline.py).

Results land in ``results/dryrun/<arch>__<shape>__<mesh>[__tag].json``.
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, *,
             rule_overrides=None, optimizer="adamw", moe_impl="onehot",
             remat=None, zero3=None, out_dir="results/dryrun", tag="",
             skip_full=False, skip_cost=False, attn_chunk=None,
             pad_q_heads=None, mesh_override=None) -> dict:
    import jax
    from repro.configs.base import SHAPES, load_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import (analytic_hbm_model,
                                       collective_bytes_per_device, cost_of,
                                       extrapolate, memory_of, model_flops,
                                       roofline_terms)
    from repro.launch.steps import build_cell
    from repro.models.model import build as build_model
    from repro.models import layers as Lmod

    if attn_chunk:
        Lmod.ATTN_CHUNK = attn_chunk

    cfg = load_arch(arch_id)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if zero3 is not None:
        cfg = dataclasses.replace(cfg, zero3=zero3)
    if pad_q_heads is not None:
        cfg = dataclasses.replace(cfg, pad_q_heads=pad_q_heads)
    shape = SHAPES[shape_name]
    if mesh_override is not None:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(**mesh_override)          # hillclimb re-meshing
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "optimizer": optimizer, "moe_impl": moe_impl,
           "remat": cfg.remat, "zero3": cfg.zero3, "tag": tag,
           "rule_overrides": rule_overrides,
           "params": cfg.param_count(), "active_params": cfg.active_param_count(),
           "model_flops": model_flops(cfg, shape),
           "analytic_hbm": analytic_hbm_model(
               cfg, shape, dict(mesh.shape), optimizer=optimizer)}

    kw = dict(rule_overrides=rule_overrides, optimizer=optimizer,
              moe_impl=moe_impl)

    with mesh:
        if not skip_full:
            t0 = time.time()
            fn, args = build_cell(cfg, shape, mesh, **kw)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
            rec["full_compile_s"] = time.time() - t0
            rec["memory"] = memory_of(compiled)
            rec["full_cost"] = cost_of(compiled)
            text = compiled.as_text()
            rec["full_collectives_per_dev"] = collective_bytes_per_device(text)
            del compiled, lowered, fn

        if not skip_cost:
            pat = len(build_model(cfg).pattern())
            costs = {}
            for mult in (1, 2):
                c = dataclasses.replace(cfg, n_layers=pat * mult,
                                        scan_layers=False)
                t0 = time.time()
                fn, args = build_cell(c, shape, mesh, **kw)
                compiled = fn.lower(*args).compile()
                cost = cost_of(compiled)
                coll = collective_bytes_per_device(compiled.as_text())
                cost["coll_bytes_per_dev"] = coll["total"]
                cost.update({f"coll_{k}": v for k, v in coll.items()
                             if k != "total"})
                costs[mult] = cost
                rec[f"cost_L{mult}_compile_s"] = time.time() - t0
                del compiled, fn
            n_groups = cfg.n_layers // pat
            ext = extrapolate(costs[1], costs[2], n_groups)
            rec["cost_L1"], rec["cost_L2"] = costs[1], costs[2]
            rec["cost_extrapolated_per_dev"] = ext
            # cost_analysis numbers are PER-DEVICE under SPMD (verified:
            # a [512,512]@[512,512] matmul model-sharded 4-ways reports
            # 2MNK/4). Globalize before the roofline.
            flops_g = ext["flops"] * chips
            bytes_g = ext["bytes"] * chips
            coll_global = ext["coll_bytes_per_dev"] * chips
            rec["roofline"] = roofline_terms(flops_g, bytes_g,
                                             coll_global, chips)
            rec["roofline"]["model_flops_ratio"] = (
                rec["model_flops"] / max(flops_g, 1.0))
            rec["roofline"]["mfu_upper_bound"] = (
                rec["model_flops"] / (chips * 197e12)
                / max(rec["roofline"]["step_time_lower_bound_s"], 1e-12))
    rec["ok"] = True
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    name = f"{arch_id}__{shape_name}__{mesh_kind}" + (f"__{tag}" if tag else "")
    (out / f"{name}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--moe-impl", default="onehot")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--zero3", default=None, type=lambda s: s == "1")
    ap.add_argument("--attn-chunk", default=None, type=int)
    ap.add_argument("--pad-q-heads", default=None, type=int)
    ap.add_argument("--mesh-shape", default=None,
                    help="override mesh, e.g. 'data=32,model=8'")
    ap.add_argument("--rules", default=None,
                    help="JSON logical-rule overrides, e.g. '{\"embed\":null}'")
    ap.add_argument("--skip-full", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import ARCH_IDS, SHAPES, applicable_shapes, load_arch

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = json.loads(args.rules) if args.rules else None

    failures = 0
    for arch_id in archs:
        cfg = load_arch(arch_id)
        shapes = (applicable_shapes(cfg) if args.shape == "all"
                  else args.shape.split(","))
        for shape_name in shapes:
            if shape_name not in applicable_shapes(cfg):
                print(f"SKIP {arch_id} × {shape_name} (per DESIGN.md rules)")
                continue
            for mesh_kind in meshes:
                name = f"{arch_id}__{shape_name}__{mesh_kind}" \
                    + (f"__{args.tag}" if args.tag else "")
                path = pathlib.Path(args.out) / f"{name}.json"
                if args.skip_existing and path.exists():
                    print(f"HAVE {name}")
                    continue
                t0 = time.time()
                try:
                    mo = None
                    if args.mesh_shape:
                        mo = {k: int(v) for k, v in
                              (kv.split("=") for kv in args.mesh_shape.split(","))}
                    rec = run_cell(arch_id, shape_name, mesh_kind,
                                   rule_overrides=overrides,
                                   optimizer=args.optimizer,
                                   moe_impl=args.moe_impl, remat=args.remat,
                                   zero3=args.zero3, out_dir=args.out,
                                   tag=args.tag, skip_full=args.skip_full,
                                   skip_cost=args.skip_cost,
                                   attn_chunk=args.attn_chunk,
                                   pad_q_heads=args.pad_q_heads,
                                   mesh_override=mo)
                    rl = rec.get("roofline", {})
                    print(f"OK   {name}  ({time.time()-t0:.0f}s) "
                          f"dom={rl.get('dominant','-')} "
                          f"step≥{rl.get('step_time_lower_bound_s', float('nan')):.4f}s "
                          f"mfu≤{rl.get('mfu_upper_bound', float('nan')):.3f}")
                except Exception as e:
                    failures += 1
                    print(f"FAIL {name}: {type(e).__name__}: {e}")
                    traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
