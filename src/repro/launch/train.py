"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_8b --tiny \
      --steps 50 --workdir /tmp/run --fail-at 20

Full-config multi-pod launches use the same code path via the dry-run's
mesh/sharding builders (launch/steps.py) on real TPU backends; on this CPU
container only tiny variants execute for real (full configs compile-only —
see launch/dryrun.py).
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced smoke config (CPU-executable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--workdir", default="/tmp/repro_train")
    ap.add_argument("--fail-at", default="")
    args = ap.parse_args()

    from repro.configs.base import load_arch, load_tiny
    from repro.train import TrainConfig, train

    cfg = load_tiny(args.arch) if args.tiny else load_arch(args.arch)
    fails = {int(s) for s in args.fail_at.split(",") if s.strip()}
    r = train(cfg, TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                               lr=args.lr, optimizer=args.optimizer),
              args.workdir, failure_at=fails,
              on_step=lambda s, l: s % 10 == 0 and print(f"step {s}: {l:.4f}"))
    print(f"final: step={r.final_step} restarts={r.restarts} "
          f"loss={r.losses[-1]:.4f} {r.steps_per_sec:.2f} steps/s")


if __name__ == "__main__":
    main()
