"""Deterministic, shard-aware, resumable synthetic token pipeline.

Production properties the tests assert:
  * determinism   — batch(step) is a pure function of (seed, step, shard);
  * resumability  — restoring from step k replays exactly the same stream
                    (no state files needed: counter-mode generation);
  * shard-awareness — each data shard draws a disjoint slice of the global
                    batch (shard i of n gets rows [i·B/n, (i+1)·B/n));
  * straggler skip-ahead — ``skip(k)`` is O(1), not O(k) (counter-based).

Synthetic corpus: a Zipfian unigram stream with Markov bigram structure, so
losses actually decrease during the example runs (a learnable signal), plus
deterministic label shift for causal LM training.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    n_shards: int = 1
    shard: int = 0


class TokenPipeline:
    """Counter-mode generator: ``batch(step)`` never mutates state."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards
        # fixed Zipf-ish unigram table + a deterministic "grammar" permutation
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._probs = p / p.sum()
        self._perm = rng.permutation(cfg.vocab)          # bigram successor map

    def _rng_for(self, step: int) -> np.random.Generator:
        c = self.cfg
        return np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.shard]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Local shard of the global batch for ``step`` (tokens + labels)."""
        c = self.cfg
        rng = self._rng_for(step)
        B, S = self.local_batch, c.seq_len
        base = rng.choice(c.vocab, size=(B, S + 1), p=self._probs)
        # 50% of positions follow the bigram grammar (learnable structure)
        follow = rng.random((B, S)) < 0.5
        succ = self._perm[base[:, :-1]]
        seq = np.where(follow, succ, base[:, 1:])
        seq = np.concatenate([base[:, :1], seq], axis=1).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def skip(self, to_step: int) -> int:
        """O(1) skip-ahead (counter mode) — straggler catch-up support."""
        return to_step
